"""Pallas TPU kernel: fused Expr-predicate evaluation to a packed bitset.

The extractor hot path (paper §4, Fig. 2) is one mask pass per scan branch.
PR 3 fused each branch's predicate chain into a single ``Expr`` conjunction,
but the executor still evaluated it as jnp mask algebra — one HBM round-trip
per column reference plus a materialized bool column (1 byte/row) that every
consumer re-reads.  This module compiles the serialized Expr tree into ONE
Pallas kernel:

  * one grid pass over the projected columns — every leaf op (comparisons,
    arithmetic, ``isin`` via sorted-membership rank compares, sentinel null
    tests, ``&``/``|``/``~``) evaluates entirely in VMEM;
  * the output is a **packed uint32 bitset** (1 bit/row, 8x smaller than a
    bool column) plus per-block popcounts: the mask pass itself never writes
    a bool column, and the words use the shared ``core.bitset`` layout.
    Since the bitset-native validity redesign, ``ColumnarTable.valid`` IS
    this packed form, so the kernel's output becomes the downstream table's
    validity verbatim — no unpack hop — and both the input validity and the
    result cross HBM at 1 bit/row into the cohort algebra
    (``bitset_ops``) and the compaction keep-mask (``filter_compact``).

Codegen is trace-time: ``compile_predicate`` walks the hashable param tree
(``expr.Expr.to_param`` form — the exact object plan nodes carry) and emits a
closure of jnp ops; ``pallas_call`` then lowers that closure per block.  The
``isin`` whitelists are static plan params, so they are sorted host-side and
streamed to every block; membership is the two monotone rank reductions
``rank(<= x) > rank(< x)`` — broadcast compares + sums, the TPU-native
formulation (no gather), exactly equivalent to sorted-array binary search.

Grid blocks are independent (`parallel` semantics); the wrapper pads ragged
tails with invalid rows, so any capacity works.
"""
from __future__ import annotations

import functools
import operator as _op
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401 (TPU lowering)

from repro.kernels import default_interpret

__all__ = [
    "DEFAULT_BLOCK", "MAX_ISIN_VALUES", "PREDICATE_ENGINES", "compilable",
    "compile_predicate", "default_interpret", "isin_vmem_bytes",
    "predicate_bitset", "resolve_engine",
]

DEFAULT_BLOCK = 1024           # rows per grid block; must be a multiple of 32

# sorted-membership is a (block x whitelist) broadcast in VMEM: at the
# default block, 1024 values ~ 4 MB of intermediate — comfortably resident;
# bigger whitelists fall back to the jnp engine instead of risking VMEM
# exhaustion on a real TPU (interpret-mode CI would never catch it)
MAX_ISIN_VALUES = 1024

# mirrors columnar.NULL_INT (kernels stay import-light: no repro.core deps,
# same convention as filter_compact's _INT_MIN)
_NULL_INT = -2_147_483_648 + 1

_CMP = {"==": _op.eq, "!=": _op.ne, "<": _op.lt, "<=": _op.le,
        ">": _op.gt, ">=": _op.ge}
_ARITH = {"+": _op.add, "-": _op.sub, "*": _op.mul,
          "//": _op.floordiv, "%": _op.mod}

# param tags whose value is boolean — the kernel packs bits, so the tree ROOT
# must be one of these (interior arithmetic is unrestricted)
_BOOL_TAGS = frozenset({"cmp", "bool", "not", "isin", "isnull", "notnull"})

# ---------------------------------------------------------------------------
# engine selection
# ---------------------------------------------------------------------------
PREDICATE_ENGINES = ("jnp", "pallas", "auto")


def resolve_engine(predicate_engine: Optional[str] = None,
                   engine: str = "xla") -> str:
    """Resolve the predicate engine for ``fused_mask``/``predicate`` nodes.

    ``"jnp"``/``"pallas"`` are explicit; ``"auto"`` (or ``None``) picks the
    Pallas bitset kernel when the global executor engine is already
    ``"pallas"`` or when running on a real TPU backend — the same
    backend-derived choice ``ops.default_interpret`` makes for compaction —
    and falls back to jnp mask algebra otherwise.
    """
    pe = predicate_engine or "auto"
    if pe not in PREDICATE_ENGINES:
        raise ValueError(f"predicate engine must be one of {PREDICATE_ENGINES}, "
                         f"got {pe!r}")
    if pe != "auto":
        return pe
    if engine == "pallas" or jax.default_backend() == "tpu":
        return "pallas"
    return "jnp"


def _isin_sizes(p, out: list) -> None:
    if not isinstance(p, tuple) or not p:
        return
    if p[0] == "isin":
        out.append(len(p[2]))
        _isin_sizes(p[1], out)
        return
    for x in p[1:]:
        _isin_sizes(x, out)


def isin_vmem_bytes(n_values: int, block: int = DEFAULT_BLOCK) -> int:
    """VMEM bytes the in-kernel sorted-membership broadcast needs for one
    ``isin`` whitelist of ``n_values`` entries: the (block x whitelist)
    comparison intermediate plus the resident table, int32 lanes.  The
    static analyzer quotes this in its engine-feasibility diagnostics so an
    oversized whitelist comes with the budget it would blow."""
    n = max(int(n_values), 1)
    return 4 * (block * n + n)


def compilable(expr_param) -> bool:
    """True when the serialized Expr can compile to the bitset kernel:

      * the root must be boolean-valued (packing bits of an arithmetic value
        would be meaningless), and
      * every ``isin`` whitelist must fit the VMEM membership budget
        (``MAX_ISIN_VALUES``; larger lists would blow the in-kernel
        broadcast on a real TPU).

    Non-compilable exprs stay on the jnp engine (``assign_engines`` stamps
    them back; the executor double-checks)."""
    if not (isinstance(expr_param, tuple) and len(expr_param) > 0
            and expr_param[0] in _BOOL_TAGS):
        return False
    sizes: list = []
    _isin_sizes(expr_param, sizes)
    return all(s <= MAX_ISIN_VALUES for s in sizes)


# ---------------------------------------------------------------------------
# Expr-param -> kernel-body codegen
# ---------------------------------------------------------------------------
def _is_null(v: jax.Array) -> jax.Array:
    if jnp.issubdtype(v.dtype, jnp.floating):
        return jnp.isnan(v)
    return v == jnp.asarray(_NULL_INT, v.dtype)


def _sorted_member(x: jax.Array, tbl: jax.Array) -> jax.Array:
    """Sorted-membership: x ∈ tbl iff rank(tbl <= x) > rank(tbl < x).

    Two monotone rank reductions over the sorted whitelist — broadcast
    compares + row sums, all VPU work in VMEM (binary search without the
    gathers TPUs lack).  NaN probes compare false both ways -> non-member,
    matching ``jnp.isin``.
    """
    rd = jnp.promote_types(x.dtype, tbl.dtype)
    xb = x.astype(rd)[:, None]
    tb = tbl.astype(rd)[None, :]
    le = (tb <= xb).sum(axis=1)
    lt = (tb < xb).sum(axis=1)
    return le > lt


def compile_predicate(expr_param: Tuple):
    """Compile a serialized Expr (``Expr.to_param`` nested tuples) into
    ``(columns, isin_tables, eval_fn)``.

    ``columns`` is the ordered tuple of column operands (the kernel's
    projected inputs); ``isin_tables`` holds one sorted (tail-padded with its
    own max, so padding can never match) numpy whitelist per ``isin`` leaf;
    ``eval_fn(env, tables)`` maps {column: block array} + table blocks to the
    boolean mask block — pure jnp, traceable inside a Pallas kernel body.
    """
    columns: List[str] = []
    tables: List[np.ndarray] = []

    def walk(p) -> Callable:
        tag = p[0]
        if tag == "col":
            name = p[1]
            if name not in columns:
                columns.append(name)
            return lambda env, tbls: env[name]
        if tag == "lit":
            v = p[1]
            return lambda env, tbls: v
        if tag == "cmp":
            f, l, r = _CMP[p[1]], walk(p[2]), walk(p[3])
            return lambda env, tbls: f(l(env, tbls), r(env, tbls))
        if tag == "arith":
            f, l, r = _ARITH[p[1]], walk(p[2]), walk(p[3])
            return lambda env, tbls: f(l(env, tbls), r(env, tbls))
        if tag == "bool":
            l, r = walk(p[2]), walk(p[3])
            if p[1] == "and":
                return lambda env, tbls: l(env, tbls) & r(env, tbls)
            return lambda env, tbls: l(env, tbls) | r(env, tbls)
        if tag == "not":
            x = walk(p[1])
            return lambda env, tbls: ~x(env, tbls)
        if tag in ("isnull", "notnull"):
            x = walk(p[1])
            if tag == "notnull":
                return lambda env, tbls: ~_is_null(jnp.asarray(x(env, tbls)))
            return lambda env, tbls: _is_null(jnp.asarray(x(env, tbls)))
        if tag == "isin":
            x = walk(p[1])
            vals = p[2]
            if not vals:   # empty whitelist matches nothing
                return lambda env, tbls: jnp.zeros(
                    jnp.shape(jnp.asarray(x(env, tbls))), bool)
            dt = np.float32 if any(isinstance(c, float) for c in vals) \
                else np.int32
            tbl = np.sort(np.asarray(vals, dt))
            pad = (-tbl.size) % 8
            if pad:        # lane-align; max-duplicate padding never matches new values
                tbl = np.concatenate([tbl, np.full(pad, tbl[-1], dt)])
            ti = len(tables)
            tables.append(tbl)
            return lambda env, tbls: _sorted_member(
                jnp.asarray(x(env, tbls)), tbls[ti])
        raise ValueError(f"unknown Expr param tag {tag!r}")

    if expr_param[0] not in _BOOL_TAGS:
        raise ValueError(
            f"pallas predicate engine needs a boolean-valued expression root, "
            f"got tag {expr_param[0]!r} (use the jnp engine)")
    eval_fn = walk(expr_param)
    return tuple(columns), tuple(tables), eval_fn


# ---------------------------------------------------------------------------
# kernel + wrapper
# ---------------------------------------------------------------------------
def _make_kernel(eval_fn: Callable, names: Sequence[str], n_tables: int):
    def _kernel(*refs):
        col_refs = refs[:len(names)]
        tbl_refs = refs[len(names):len(names) + n_tables]
        valid_ref = refs[len(names) + n_tables]
        words_ref, pc_ref = refs[-2:]

        from repro.kernels import unpack_words_block

        env = {nm: r[...] for nm, r in zip(names, col_refs)}
        tbls = [r[...] for r in tbl_refs]
        # validity arrives PACKED (1 bit/row of HBM); expand in VMEM only
        m = eval_fn(env, tbls) & unpack_words_block(valid_ref[...])

        B = m.shape[0]
        lanes = jax.lax.broadcasted_iota(jnp.uint32, (B // 32, 32), 1)
        bits = m.reshape(B // 32, 32).astype(jnp.uint32) << lanes
        words_ref[...] = bits.sum(axis=1).astype(jnp.uint32)
        pc_ref[0] = m.astype(jnp.int32).sum()

    return _kernel


def predicate_bitset_blocks(expr_param: Tuple, cols: Dict[str, jax.Array],
                            valid_words: jax.Array, block: int = DEFAULT_BLOCK,
                            interpret: Optional[bool] = None):
    """One fused pass: evaluate ``expr_param`` over ``cols`` AND the packed
    ``valid_words`` bitset (``core.bitset`` layout — validity is streamed at
    1 bit/row, not a bool column).

    Returns ``(words, popcounts)`` — the packed uint32 bitset (n/32 words)
    and the per-block popcounts.  Column length must be a multiple of
    ``block`` (``predicate_bitset`` pads); ``block`` a multiple of 32;
    ``valid_words`` holds exactly n/32 words.
    """
    interpret = default_interpret() if interpret is None else interpret
    assert block % 32 == 0, block
    n = valid_words.shape[0] * 32
    assert n % block == 0, (n, block)
    grid = (n // block,)
    names, tables, eval_fn = compile_predicate(expr_param)
    missing = [nm for nm in names if nm not in cols]
    if missing:
        raise KeyError(f"predicate reads absent column(s) {missing}")

    in_specs = [pl.BlockSpec((block,), lambda g: (g,)) for _ in names]
    in_specs += [pl.BlockSpec((int(t.size),), lambda g: (0,)) for t in tables]
    in_specs += [pl.BlockSpec((block // 32,), lambda g: (g,))]
    operands = ([cols[nm] for nm in names]
                + [jnp.asarray(t) for t in tables]
                + [valid_words.astype(jnp.uint32)])
    return pl.pallas_call(
        _make_kernel(eval_fn, names, len(tables)),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block // 32,), lambda g: (g,)),
            pl.BlockSpec((1,), lambda g: (g,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n // 32,), jnp.uint32),
            jax.ShapeDtypeStruct((grid[0],), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)


def _pad_to(x: jax.Array, mult: int, fill=0):
    n = x.shape[0]
    p = (-n) % mult
    if p == 0:
        return x
    return jnp.concatenate([x, jnp.full((p,), fill, x.dtype)])


@functools.partial(jax.jit,
                   static_argnames=("expr_param", "block", "interpret", "n"))
def _predicate_bitset_jit(columns: Dict[str, jax.Array], words: jax.Array, *,
                          expr_param: Tuple, block: int,
                          interpret: Optional[bool], n: int):
    if n == 0:
        return jnp.zeros((0,), jnp.uint32), jnp.int32(0)
    cols = {nm: _pad_to(c, block) for nm, c in columns.items()}
    wp = _pad_to(words, block // 32)
    out, pc = predicate_bitset_blocks(expr_param, cols, wp, block=block,
                                      interpret=interpret)
    return out[: (n + 31) // 32], pc.sum().astype(jnp.int32)


def predicate_bitset(columns: Dict[str, jax.Array], valid: jax.Array, *,
                     expr_param: Tuple, block: int = DEFAULT_BLOCK,
                     interpret: Optional[bool] = None,
                     capacity: Optional[int] = None):
    """Fused predicate -> packed bitset over a table's columns.

    ``valid`` is the table's validity: the canonical packed uint32 word form
    (``ColumnarTable.valid``) or a legacy ``(n,) bool`` row mask, which is
    packed at the boundary.  Returns ``(words, count)``: ``words`` is the
    ceil(n/32)-word uint32 bitset of ``valid & expr`` (row i lives at word
    i//32, bit i%32 — the shared ``core.bitset`` layout, so the result drops
    straight into the table validity and the cohort algebra kernel),
    ``count`` the total surviving rows.  Columns are padded to the block
    quantum with invalid rows.  Only the columns the expression reads are
    passed into the jit boundary — handing in a whole wide table costs
    nothing extra and never retraces on unrelated columns.  ``capacity``
    names the row count when ``valid`` is packed; it defaults to the first
    column's length.
    """
    names, _, _ = compile_predicate(expr_param)
    missing = [nm for nm in names if nm not in columns]
    if missing:
        raise KeyError(f"predicate reads absent column(s) {missing}")
    if getattr(valid, "dtype", None) == jnp.uint32:
        if capacity is None:
            if not names:
                raise ValueError("packed valid needs an explicit capacity "
                                 "when the predicate reads no columns")
            capacity = int(columns[names[0]].shape[0])
        words = valid
    else:
        valid = jnp.asarray(valid, bool)
        capacity = int(valid.shape[0])
        from repro.core.bitset import pack as _pack

        words = _pack(valid)
    return _predicate_bitset_jit({nm: columns[nm] for nm in names}, words,
                                 expr_param=expr_param, block=block,
                                 interpret=interpret, n=capacity)
