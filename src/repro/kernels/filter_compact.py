"""Pallas TPU kernel: fused predicate + block-local stream compaction.

The extractor hot path (paper Fig. 2): after mask algebra, the single
materialization is compacting surviving rows to the front.  On GPU this is a
warp-scan + scattered writes; TPUs have no efficient in-register scatter, so
the TPU-native formulation is:

  * per block: exclusive prefix-sum of the keep-mask gives each surviving row
    its target slot; the in-block permutation is realized as a broadcast
    compare (slot == target) + masked max-reduction over the row axis — an
    O(B²) VPU sweep that stays entirely in VMEM and beats gather/scatter on
    the MXU-era memory system for B ≤ 512;
  * per block count is emitted so the (cheap) cross-block stitch — one gather
    with offsets = cumsum(counts) — runs as a single fused XLA op in the
    wrapper (``ops.filter_compact``).

Grid iterations are independent (`parallel` semantics): this kernel scales to
arbitrarily long columns and is the per-shard body of the distributed
extraction (each mesh shard compacts its patient partition locally).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 256
_INT_MIN = -2_147_483_648


def _compact_body(v, m, out_ref, cnt_ref):
    """Shared block-compaction body: values ``v`` + bool keep mask ``m``."""
    B = v.shape[0]

    keep = m.astype(jnp.int32)
    tgt = jnp.cumsum(keep) - 1                     # target slot per kept row
    slots = jax.lax.broadcasted_iota(jnp.int32, (B, B), 0)   # out slot j
    rows = jax.lax.broadcasted_iota(jnp.int32, (B, B), 1)    # in row i
    sel = (tgt[None, :] == slots) & m[None, :]     # (j, i) one-hot per slot

    if jnp.issubdtype(v.dtype, jnp.floating):
        fill = jnp.asarray(-jnp.inf, v.dtype)
        picked = jnp.where(sel, v[None, :], fill).max(axis=1)
        empty = jnp.asarray(0, v.dtype)
    else:
        picked = jnp.where(sel, v[None, :], jnp.asarray(_INT_MIN, v.dtype)).max(axis=1)
        empty = jnp.asarray(0, v.dtype)

    cnt = keep.sum()
    lane = jax.lax.broadcasted_iota(jnp.int32, (B,), 0)
    out_ref[...] = jnp.where(lane < cnt, picked, empty)
    cnt_ref[0] = cnt


def _kernel(vals_ref, mask_ref, out_ref, cnt_ref):
    _compact_body(vals_ref[...], mask_ref[...] != 0, out_ref, cnt_ref)


def _kernel_bits(vals_ref, words_ref, out_ref, cnt_ref):
    """Bitset keep-mask variant: the mask arrives PACKED (``core.bitset``
    layout, (B//32,) uint32 per block — 1 bit/row of HBM traffic instead of
    the int8 mask's byte/row) and is expanded in VMEM only."""
    from repro.kernels import unpack_words_block

    _compact_body(vals_ref[...], unpack_words_block(words_ref[...]),
                  out_ref, cnt_ref)


def filter_compact_bits_blocks(vals: jax.Array, words: jax.Array,
                               block: int = DEFAULT_BLOCK,
                               interpret: bool | None = None):
    """Block-compact ``vals`` by a packed keep-mask bitset.

    Same contract as ``filter_compact_blocks`` but the keep mask is the
    canonical packed uint32 word array (``ColumnarTable.valid`` /
    ``kernels.predicate`` output) — ``words[i // 32] >> (i % 32) & 1`` keeps
    row ``i``.  ``vals`` must be block-quantized with ``words`` holding
    exactly ``len(vals) / 32`` words (the ``ops.filter_compact`` wrapper
    pads; bits past the original length must be 0 — the bitset tail
    invariant).  ``block`` must be a multiple of 32.
    """
    from repro.kernels import default_interpret

    interpret = default_interpret() if interpret is None else interpret
    assert block % 32 == 0, block
    n = vals.shape[0]
    if n == 0:
        return jnp.zeros((0,), vals.dtype), jnp.zeros((0,), jnp.int32)
    assert n % block == 0 and words.shape[0] * 32 == n, (n, words.shape)
    grid = (n // block,)
    return pl.pallas_call(
        _kernel_bits,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda g: (g,)),
            pl.BlockSpec((block // 32,), lambda g: (g,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda g: (g,)),
            pl.BlockSpec((1,), lambda g: (g,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), vals.dtype),
            jax.ShapeDtypeStruct((grid[0],), jnp.int32),
        ],
        interpret=interpret,
    )(vals, words.astype(jnp.uint32))


def filter_compact_blocks(vals: jax.Array, mask: jax.Array, block: int = DEFAULT_BLOCK,
                          interpret: bool | None = None):
    """Block-compact ``vals`` by ``mask``.

    Returns ``(block_vals, block_counts)`` with ``block_vals[g]`` holding the
    ``block_counts[g]`` surviving rows of grid block ``g`` at its front.
    Ragged tails are padded with dropped (mask=False) rows — padded rows can
    never surface in a compacted block; the padded tail is returned (callers
    slice).  ``interpret`` defaults by backend (interpret mode off-TPU).
    """
    from repro.kernels import default_interpret

    interpret = default_interpret() if interpret is None else interpret
    n = vals.shape[0]
    if n == 0:
        return jnp.zeros((0,), vals.dtype), jnp.zeros((0,), jnp.int32)
    pad = (-n) % block
    if pad:
        vals = jnp.concatenate([vals, jnp.zeros((pad,), vals.dtype)])
        mask = jnp.concatenate([mask.astype(bool),
                                jnp.zeros((pad,), bool)])
        n += pad
    grid = (n // block,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda g: (g,)),
            pl.BlockSpec((block,), lambda g: (g,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda g: (g,)),
            pl.BlockSpec((1,), lambda g: (g,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), vals.dtype),
            jax.ShapeDtypeStruct((grid[0],), jnp.int32),
        ],
        interpret=interpret,
    )(vals, mask.astype(jnp.int8))
