"""Public jit'd wrappers around the Pallas kernels.

Each wrapper pads inputs to kernel-aligned sizes, invokes the kernel, and
performs the (cheap) cross-block stitches.  ``interpret`` defaults to True
unless running on a real TPU backend — the kernels are TPU-targeted and
validated in interpret mode on CPU (container constraint).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret  # noqa: F401 (shared resolver)
from repro.kernels import filter_compact as _fc
from repro.kernels import segment_scan as _ss
from repro.kernels import bitset_ops as _bo
from repro.kernels import hash_partition as _hp
from repro.kernels import swa_attention as _swa
from repro.kernels.predicate import predicate_bitset  # noqa: F401 (re-export;
# pads + jits itself — see kernels/predicate.py for the Expr->bitset codegen)

__all__ = [
    "default_interpret",
    "filter_compact",
    "segmented_scan",
    "bitset_op",
    "hash_partition_plan",
    "flash_attention",
    "predicate_bitset",
]


def _pad_to(x: jax.Array, mult: int, fill=0):
    n = x.shape[0]
    p = (-n) % mult
    if p == 0:
        return x
    return jnp.concatenate([x, jnp.full((p,) + x.shape[1:], fill, x.dtype)])


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def filter_compact(vals: jax.Array, mask: jax.Array, block: int = 256,
                   interpret: bool | None = None):
    """Compact ``vals[mask]`` to the front; returns (vals_out, count).

    ``mask`` is a ``(n,) bool`` row mask or — the bitset-native hot path —
    the packed ``(ceil(n/32),) uint32`` keep-mask (``ColumnarTable.valid`` /
    predicate-kernel output; searchsorted over the per-block popcount
    cumsums drives the stitch either way, but the packed form streams the
    keep mask at 1 bit/row).  Kernel does block-local compaction; the
    cross-block stitch is a single gather driven by cumsum of per-block
    counts.
    """
    interpret = default_interpret() if interpret is None else interpret
    n = vals.shape[0]
    if n == 0:
        return vals, jnp.int32(0)
    vp = _pad_to(vals, block)
    if getattr(mask, "dtype", None) == jnp.uint32:
        wp = _pad_to(mask, block // 32)      # zero words: padded rows dropped
        blocks, counts = _fc.filter_compact_bits_blocks(
            vp, wp, block=block, interpret=interpret)
    else:
        mp = _pad_to(mask.astype(bool), block, fill=False)
        blocks, counts = _fc.filter_compact_blocks(vp, mp, block=block, interpret=interpret)
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts).astype(jnp.int32)])
    total = offs[-1]
    pos = jnp.arange(vp.shape[0], dtype=jnp.int32)
    blk = jnp.clip(jnp.searchsorted(offs, pos, side="right") - 1, 0, counts.shape[0] - 1)
    src = blk * block + (pos - offs[blk])
    out = jnp.where(pos < total, blocks[jnp.clip(src, 0, vp.shape[0] - 1)],
                    jnp.asarray(0, vals.dtype))
    return out[:n], jnp.minimum(total, n)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def segmented_scan(flags: jax.Array, vals: jax.Array, block: int = 512,
                   interpret: bool | None = None):
    """Inclusive segmented (min, max, count) scan; flags start runs."""
    interpret = default_interpret() if interpret is None else interpret
    n = vals.shape[0]
    fp = _pad_to(flags.astype(bool), block, fill=True)
    vp = _pad_to(vals, block)
    mn, mx, ct = _ss.segmented_scan(fp, vp, block=block, interpret=interpret)
    return mn[:n], mx[:n], ct[:n]


@functools.partial(jax.jit, static_argnames=("op", "block", "interpret"))
def bitset_op(a: jax.Array, b: jax.Array, op: str, block: int = 1024,
              interpret: bool | None = None):
    """Fused bitwise op + total popcount; returns (words, count)."""
    interpret = default_interpret() if interpret is None else interpret
    n = a.shape[0]
    if n == 0:
        return a, jnp.int32(0)
    # the kernel pads ragged tails itself; returns the padded words
    words, partial = _bo.bitset_op_popcount(a, b, op, block=block,
                                            interpret=interpret)
    return words[:n], partial.sum().astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_dest", "block", "interpret"))
def hash_partition_plan(keys: jax.Array, valid: jax.Array, n_dest: int, block: int = 512,
                        interpret: bool | None = None):
    """Shuffle plan: (dest, rank-within-block, per-block histograms)."""
    interpret = default_interpret() if interpret is None else interpret
    n = keys.shape[0]
    kp = _pad_to(keys, block)
    vp = _pad_to(valid.astype(bool), block, fill=False)
    dest, rank, hist = _hp.hash_partition_plan(kp, vp, n_dest, block=block, interpret=interpret)
    return dest[:n], rank[:n], hist


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int | None = None, bq: int = 128, bk: int = 128,
                    interpret: bool | None = None):
    """Flash attention (GQA, causal, sliding window); pads seq dims to blocks."""
    interpret = default_interpret() if interpret is None else interpret
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    if q_offset is None:
        q_offset = Skv - Sq
    bq_ = min(bq, max(8, Sq))
    bk_ = min(bk, max(8, Skv))
    pq = (-Sq) % bq_
    pk = (-Skv) % bk_
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    # kv_len masks padded KV rows in-kernel; padded q rows are discarded on
    # unpad below.
    out = _swa.flash_swa_attention(
        qp, kp, vp, causal=causal, window=window, q_offset=q_offset,
        kv_len=Skv, bq=bq_, bk=bk_, interpret=interpret,
    )
    return out[:, :, :Sq, :]
