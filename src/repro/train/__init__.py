from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.train.train_step import make_train_step, init_train_state, abstract_train_state
from repro.train.checkpointing import (
    save_checkpoint, restore_checkpoint, AsyncCheckpointer, latest_step,
)
from repro.train import grad_compression
