"""Train-step builder: loss -> grads -> clip -> AdamW, with optional
microbatch gradient accumulation (compute/comm overlap: the all-reduce of
microbatch k overlaps microbatch k+1's compute under XLA's latency-hiding
scheduler) and optional cross-pod gradient compression.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.registry import ModelBundle
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.grad_compression import compress_grads_crosspod

__all__ = ["TrainState", "make_train_step", "init_train_state"]

TrainState = Dict[str, Any]  # {"params": ..., "opt": adamw state}


def init_train_state(bundle: ModelBundle, key) -> TrainState:
    params = bundle.init(key)
    return {"params": params, "opt": adamw_init(params)}


def abstract_train_state(bundle: ModelBundle) -> TrainState:
    from repro.train.optimizer import abstract_opt_state

    pa = bundle.abstract_params()
    return {"params": pa, "opt": abstract_opt_state(pa)}


def make_train_step(
    bundle: ModelBundle,
    opt_cfg: Optional[AdamWConfig] = None,
    microbatches: int = 1,
    compress_crosspod: bool = False,
    pod_axis: Optional[str] = None,
) -> Callable[[TrainState, Dict[str, jax.Array]], Any]:
    """Builds ``train_step(state, batch) -> (state, metrics)``.

    ``microbatches > 1``: the global batch is split on axis 0 and gradients
    accumulate over a ``lax.scan`` — the standard overlap/memory trade.
    ``compress_crosspod``: int8 error-feedback compression on the cross-pod
    gradient reduction (see grad_compression.py); intra-pod reduction stays
    full-precision (ICI is cheap, DCN is not).
    """
    opt_cfg = opt_cfg or AdamWConfig()

    def loss_fn(params, batch):
        return bundle.train_loss(params, batch)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        params = state["params"]
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc_body(carry, mb_i):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb_i)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc_body, (zero, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches

        if compress_crosspod and pod_axis:
            grads = compress_grads_crosspod(grads, pod_axis)

        new_params, new_opt, metrics = adamw_update(opt_cfg, grads, state["opt"])
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
