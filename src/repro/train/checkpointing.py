"""Fault-tolerant checkpointing: sharded, async, atomic, elastic.

Design for 1000+ nodes (DESIGN.md §5):
  * every leaf is saved with its *logical* (unsharded) index space — restore
    can therefore reshard onto a different mesh (elastic scaling / failed-node
    replacement with a smaller pod);
  * writes go to a temp dir and are atomically renamed; a manifest records
    (step, arch, mesh shape, data cursor, rng) so a restarted job replays the
    exact data stream (the pipeline is deterministic given (seed, step));
  * saving runs on a background thread (async) — training continues while
    host DMA + serialization drain;
  * restore validates the manifest and re-device_puts with the *current*
    mesh's shardings.

In this container (1 host) the "sharded" writes collapse to full arrays; the
code paths are identical.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "AsyncCheckpointer", "latest_step"]


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, state: Any,
                    meta: Optional[Dict] = None) -> str:
    """Atomic checkpoint write; returns the final path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    names, leaves, _ = _flatten_with_names(state)
    arrays = {}
    dtypes = {}
    for n, leaf in zip(names, leaves):
        a = np.asarray(jax.device_get(leaf))
        dtypes[n] = str(a.dtype)
        if a.dtype.kind not in "biufc":  # ml_dtypes (bf16/fp8): npz-safe view
            a = a.view(np.dtype(f"u{a.dtype.itemsize}"))
        arrays[n] = a
    np.savez(os.path.join(tmp, "state.npz"), **arrays)
    manifest = {
        "step": step,
        "time": time.time(),
        "leaves": {n: {"shape": list(arrays[n].shape), "dtype": dtypes[n]}
                   for n in arrays},
        **(meta or {}),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, state_template: Any,
                       shardings: Optional[Any] = None) -> Any:
    """Restore onto the current mesh.  ``shardings`` (same pytree as state)
    enables elastic resharding: arrays are device_put with the new layout
    regardless of the saving mesh."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest["step"] != step:
        raise ValueError(f"manifest step {manifest['step']} != {step}")
    data = np.load(os.path.join(path, "state.npz"))
    names, leaves, treedef = _flatten_with_names(state_template)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for n, template, sh in zip(names, leaves, shard_leaves):
        arr = data[n]
        if tuple(arr.shape) != tuple(template.shape):
            raise ValueError(f"{n}: checkpoint shape {arr.shape} != {template.shape}")
        saved_dtype = np.dtype(manifest["leaves"][n]["dtype"])
        if arr.dtype != saved_dtype:
            arr = arr.view(saved_dtype)  # undo the npz-safe uint view
        if arr.dtype != template.dtype:
            arr = arr.astype(template.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


class AsyncCheckpointer:
    """Background-thread checkpointer; at most one write in flight."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, state: Any, meta: Optional[Dict] = None) -> None:
        self.wait()
        # materialize on host *before* returning control (state may be donated)
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            save_checkpoint(self.ckpt_dir, step, host_state, meta)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"))
