"""AdamW with fp32 master weights + moments (mixed-precision convention).

State layout mirrors the params pytree: ``{master, m, v, step}``.  Under the
ZeRO-1 shardings of ``distributed.sharding.opt_state_shardings`` the three
fp32 trees shard over the data axis on top of TP, so optimizer memory per chip
is ~12 bytes/param / (dp·tp-share) — the standard sharded-optimizer layout.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_lr"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = cfg.lr_peak * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * cfg.lr_peak * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos).astype(jnp.float32)


def adamw_init(params: Any) -> Dict[str, Any]:
    # copy=True: fp32 params must NOT alias their master copy (donating the
    # train state would otherwise donate one buffer twice).
    f32 = lambda p: jnp.array(p, jnp.float32, copy=True)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(params_abstract: Any) -> Dict[str, Any]:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "master": jax.tree.map(f32, params_abstract),
        "m": jax.tree.map(f32, params_abstract),
        "v": jax.tree.map(f32, params_abstract),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def _global_norm(grads: Any) -> jax.Array:
    leaves = [jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads: Any, opt_state: Dict[str, Any],
                 param_dtype=jnp.bfloat16):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = cosine_lr(cfg, step)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, master, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        new_master = master - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                    + cfg.weight_decay * master)
        return new_master, m, v

    flat = jax.tree.map(upd, grads, opt_state["master"], opt_state["m"],
                        opt_state["v"])
    new_master = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
    new_params = jax.tree.map(lambda p: p.astype(param_dtype), new_master)
    new_state = {"master": new_master, "m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
