"""Cross-pod gradient compression: int8 quantization with error feedback.

At 2+ pods the gradient reduction crosses the data-center network (DCN),
~10-30x slower per byte than ICI.  Standard mitigation (1-bit Adam / DALL-E
style): reduce full precision *inside* the pod, quantize to int8 with a
per-tensor scale for the *cross-pod* hop, and carry the quantization error
into the next step (error feedback keeps SGD convergence guarantees).

Implementation note: under ``jit`` + sharding, the cross-pod reduction is
XLA's; we expose the quantize/dequantize pair and a psum-based shard_map
variant for explicit-collective setups, plus the error-feedback buffer logic.
Tests validate the error-feedback contraction property.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "ef_compress_step",
           "compress_grads_crosspod"]


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8; returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_step(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One error-feedback step: compress (g + err), return (decompressed,
    new_err).  ||new_err|| is bounded by the quantization bin width."""
    target = g.astype(jnp.float32) + err
    q, s = quantize_int8(target)
    deq = dequantize_int8(q, s)
    return deq, target - deq


def compress_grads_crosspod(grads: Any, pod_axis: str) -> Any:
    """Quantize-dequantize gradients so the partitioner's cross-pod
    all-reduce moves int8-equivalent information.

    Inside jit we cannot split XLA's single all-reduce into hierarchy pieces
    directly; instead the quantize-dequantize pair bounds the information
    (and in the shard_map launcher path, `psum_compressed` below moves actual
    int8 over the pod axis).  Error feedback lives in the launcher state for
    the shard_map path (see launch/train.py).
    """
    def qdq(g):
        q, s = quantize_int8(g)
        return dequantize_int8(q, s).astype(g.dtype)

    return jax.tree.map(qdq, grads)


def psum_compressed(g: jax.Array, axis_name: str) -> jax.Array:
    """shard_map building block: int8 the payload, psum, dequantize.

    Scales are psum'd separately (tiny); the payload all-reduce moves 1/4 of
    the bf16 bytes over the slow axis.
    """
    q, s = quantize_int8(g)
    # move int8 as int32 partial sums would overflow at >=2^23 summands; at
    # pod counts (2-64) int32 accumulate of int8 is exact.
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale = jax.lax.pmax(s, axis_name)  # conservative shared scale
    return total.astype(jnp.float32) * scale
