"""Recurrent mixers: RG-LRU (Griffin / recurrentgemma) and xLSTM blocks.

RG-LRU is a gated *linear* recurrence -> ``associative_scan`` for training
(O(log S) depth) and an O(1) cell update for decode.

mLSTM (matrix memory) trains in its stabilized parallel (attention-like) form
and decodes with an O(1) (C, n, m) state update.  sLSTM has a genuinely
nonlinear recurrence (hidden-to-gate feedback), so training uses ``lax.scan``
— the one sequential layer family, as in the paper.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

Params = Dict[str, jax.Array]

_C_RGLRU = 8.0  # Griffin's fixed recurrence sharpness


# ---------------------------------------------------------------------------
# RG-LRU block (Griffin recurrent residual block body)
# ---------------------------------------------------------------------------
def rglru_params(key, cfg: ModelConfig, dtype) -> Params:
    d, r = cfg.d_model, cfg.d_rnn_
    ks = jax.random.split(key, 6)
    return {
        "wx": dense_init(ks[0], (d, r), dtype),
        "wgate": dense_init(ks[1], (d, r), dtype),
        "conv": dense_init(ks[2], (cfg.conv_width, r), dtype, scale=0.1),
        "wi": dense_init(ks[3], (r, r), dtype),
        "wr": dense_init(ks[4], (r, r), dtype),
        # Λ init so a^c ≈ 0.9..0.999 (Griffin appendix)
        "lam": jnp.asarray(
            jnp.log(jnp.expm1(jnp.linspace(0.9, 4.0, r))), jnp.float32
        ),
        "wo_r": dense_init(ks[5], (r, d), dtype),
    }


def _causal_conv1d(u: jax.Array, w: jax.Array,
                   state: Optional[jax.Array] = None):
    """Depthwise causal conv; u: (B, S, R), w: (cw, R).

    With ``state`` (B, cw-1, R) — decode: returns (y, new_state).
    """
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
        ext = jnp.concatenate([pad, u], axis=1)
    else:
        ext = jnp.concatenate([state, u], axis=1)
    y = sum(ext[:, i : i + u.shape[1], :] * w[i] for i in range(cw))
    new_state = ext[:, -(cw - 1):, :] if cw > 1 else None
    return y, new_state


def rglru(p: Params, x: jax.Array, cfg: ModelConfig,
          state: Optional[Tuple[jax.Array, jax.Array]] = None):
    """RG-LRU mixer.  x: (B, S, d).  state = (h (B,R), conv (B,cw-1,R)).

    Returns (out (B,S,d), new_state).
    """
    B, S, _ = x.shape
    u = x @ p["wx"]
    conv_state = state[1] if state is not None else None
    u, new_conv = _causal_conv1d(u, p["conv"], conv_state)

    uf = u.astype(jnp.float32)
    i_gate = jax.nn.sigmoid(uf @ p["wi"].astype(jnp.float32))
    r_gate = jax.nn.sigmoid(uf @ p["wr"].astype(jnp.float32))
    log_a = -_C_RGLRU * jax.nn.softplus(p["lam"]) * r_gate   # (B,S,R) < 0
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i_gate * uf)

    if state is None:
        # h_t = a_t h_{t-1} + b_t  — associative linear recurrence
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        _, h = jax.lax.associative_scan(combine, (a, gated_x), axis=1)
        new_h = h[:, -1, :]
    else:
        h_prev = state[0].astype(jnp.float32)
        # S small (decode step): unrolled scan
        hs = []
        h_t = h_prev
        for t in range(S):
            h_t = a[:, t] * h_t + gated_x[:, t]
            hs.append(h_t)
        h = jnp.stack(hs, axis=1)
        new_h = h_t

    gate = jax.nn.gelu((x @ p["wgate"]).astype(jnp.float32))
    out = (h * gate).astype(x.dtype) @ p["wo_r"]
    return out, (new_h.astype(x.dtype), new_conv)


def rglru_init_state(cfg: ModelConfig, batch: int, dtype):
    r = cfg.d_rnn_
    return (
        jnp.zeros((batch, r), dtype),
        jnp.zeros((batch, cfg.conv_width - 1, r), dtype),
    )


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory block)
# ---------------------------------------------------------------------------
def mlstm_params(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], (d, d), dtype),
        "wk": dense_init(ks[1], (d, d), dtype),
        "wv": dense_init(ks[2], (d, d), dtype),
        "wi": dense_init(ks[3], (d, h), jnp.float32),
        "wf": dense_init(ks[4], (d, h), jnp.float32),
        "wog": dense_init(ks[5], (d, d), dtype),
        "wo_m": dense_init(ks[6], (d, d), dtype),
    }


def mlstm(p: Params, x: jax.Array, cfg: ModelConfig,
          state: Optional[Tuple] = None):
    """mLSTM mixer; parallel form (train/prefill) or recurrent (decode).

    state = (C (B,H,hd,hd), n (B,H,hd), m (B,H)).
    """
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, H, hd) / (hd ** 0.5)
    v = (x @ p["wv"]).reshape(B, S, H, hd)
    xf = x.astype(jnp.float32)
    log_i = xf @ p["wi"]                                  # (B,S,H)
    log_f = jax.nn.log_sigmoid(xf @ p["wf"])              # (B,S,H) <= 0

    if state is None:
        # stabilized parallel form (paper eq. 19-27)
        F = jnp.cumsum(log_f, axis=1)                     # (B,S,H)
        # L[t,s] = log_i[s] + F[t] - F[s]  (s <= t)
        Lq = F                                            # per-query
        Lk = log_i - F                                    # per-key
        Lmat = Lq[:, :, None, :] + Lk[:, None, :, :]       # (B,S_q,S_k,H)
        tpos = jnp.arange(S)
        causal = tpos[:, None] >= tpos[None, :]
        Lmat = jnp.where(causal[None, :, :, None], Lmat, -jnp.inf)
        m = jnp.max(Lmat, axis=2)                         # (B,S,H)
        Dmat = jnp.exp(Lmat - m[:, :, None, :])           # (B,S,S,H)
        qk = jnp.einsum("bthd,bshd->btsh", q.astype(jnp.float32),
                        k.astype(jnp.float32))
        Smat = qk * Dmat
        norm = jnp.maximum(jnp.abs(Smat.sum(axis=2)), jnp.exp(-m))  # (B,S,H)
        h = jnp.einsum("btsh,bshd->bthd", Smat / norm[:, :, None, :],
                       v.astype(jnp.float32))
        # decode-compatible final state
        mT = m[:, -1]
        decay = jnp.exp(F[:, -1][:, None, :] - F + log_i - mT[:, None, :])
        C_end = jnp.einsum("bsh,bshd,bshe->bhde", decay, k.astype(jnp.float32),
                           v.astype(jnp.float32))
        n_end = jnp.einsum("bsh,bshd->bhd", decay, k.astype(jnp.float32))
        new_state = (C_end, n_end, mT)
    else:
        C, n, m_prev = state
        hs = []
        for t in range(S):
            m_new = jnp.maximum(log_f[:, t] + m_prev, log_i[:, t])    # (B,H)
            fdec = jnp.exp(log_f[:, t] + m_prev - m_new)[:, :, None]
            idec = jnp.exp(log_i[:, t] - m_new)[:, :, None]
            kt = k[:, t].astype(jnp.float32)
            vt = v[:, t].astype(jnp.float32)
            C = fdec[..., None] * C + idec[..., None] * jnp.einsum(
                "bhd,bhe->bhde", kt, vt)
            n = fdec * n + idec * kt
            qt = q[:, t].astype(jnp.float32)
            num = jnp.einsum("bhde,bhd->bhe", C, qt)
            den = jnp.maximum(
                jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt)), jnp.exp(-m_new)
            )[:, :, None]
            hs.append(num / den)
            m_prev = m_new
        h = jnp.stack(hs, axis=1)
        new_state = (C, n, m_prev)

    og = jax.nn.sigmoid(x @ p["wog"])
    out = (og * h.reshape(B, S, d).astype(x.dtype)) @ p["wo_m"]
    return out, new_state


def mlstm_init_state(cfg: ModelConfig, batch: int):
    H = cfg.n_heads
    hd = cfg.d_model // H
    return (
        jnp.zeros((batch, H, hd, hd), jnp.float32),
        jnp.zeros((batch, H, hd), jnp.float32),
        jnp.full((batch, H), -1e30, jnp.float32),
    )


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory block)
# ---------------------------------------------------------------------------
def slstm_params(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 9)
    p = {}
    for i, g in enumerate(("i", "f", "z", "o")):
        p[f"in_{g}"] = dense_init(ks[i], (d, d), jnp.float32)
        p[f"r_{g}"] = dense_init(ks[4 + i], (H, hd, hd), jnp.float32, scale=hd ** -0.5)
    p["wo_s"] = dense_init(ks[8], (d, d), dtype)
    return p


def slstm(p: Params, x: jax.Array, cfg: ModelConfig,
          state: Optional[Tuple] = None):
    """sLSTM mixer: sequential scan (hidden-to-gate recurrence).

    state = (c, n, h, m) each (B, H, hd).
    """
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    xf = x.astype(jnp.float32)
    zi = (xf @ p["in_i"]).reshape(B, S, H, hd)
    zf = (xf @ p["in_f"]).reshape(B, S, H, hd)
    zz = (xf @ p["in_z"]).reshape(B, S, H, hd)
    zo = (xf @ p["in_o"]).reshape(B, S, H, hd)

    if state is None:
        c0 = jnp.zeros((B, H, hd), jnp.float32)
        state = (c0, c0, c0, jnp.full((B, H, hd), -1e30, jnp.float32))

    def step(carry, t_in):
        c, n, h, m = carry
        xi, xfg, xz, xo = t_in
        gi = xi + jnp.einsum("bhd,hde->bhe", h, p["r_i"])
        gf = xfg + jnp.einsum("bhd,hde->bhe", h, p["r_f"])
        gz = jnp.tanh(xz + jnp.einsum("bhd,hde->bhe", h, p["r_z"]))
        go = jax.nn.sigmoid(xo + jnp.einsum("bhd,hde->bhe", h, p["r_o"]))
        log_f = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(log_f + m, gi)
        fdec = jnp.exp(log_f + m - m_new)
        idec = jnp.exp(gi - m_new)
        c_new = fdec * c + idec * gz
        n_new = fdec * n + idec
        h_new = go * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    xs = (
        zi.transpose(1, 0, 2, 3), zf.transpose(1, 0, 2, 3),
        zz.transpose(1, 0, 2, 3), zo.transpose(1, 0, 2, 3),
    )
    new_state, hs = jax.lax.scan(step, state, xs)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d)
    out = h.astype(x.dtype) @ p["wo_s"]
    return out, new_state


def slstm_init_state(cfg: ModelConfig, batch: int):
    H = cfg.n_heads
    hd = cfg.d_model // H
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return (z, z, z, jnp.full((batch, H, hd), -1e30, jnp.float32))
