"""Transformer layer library: norms, RoPE, GQA attention (full / sliding-
window / cross), GLU FFN, and sort-based-dispatch MoE.

Conventions:
  * params are nested dicts of ``jnp`` arrays (bf16 by default); functions are
    pure ``apply(params, x, ...)``;
  * attention is expressed as einsums + mask algebra so the XLA SPMD
    partitioner can shard it along batch / heads / sequence as the mesh
    dictates (the Pallas ``swa_attention`` kernel is the TPU-serving fast
    path, selected by ``attn_impl='pallas'``);
  * all masks are built from ``broadcasted_iota`` comparisons with traced
    offsets, so the same code traces for train, prefill and decode.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import hints

Params = Dict[str, jax.Array]

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) with D even; positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = (theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def attn_params(key, cfg: ModelConfig, dtype, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd), dtype),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd), dtype),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd), dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d), dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def _proj_qkv(p: Params, x: jax.Array, cfg: ModelConfig,
              kv_input: Optional[jax.Array] = None):
    B, S, _ = x.shape
    hd = cfg.head_dim_
    kv_src = x if kv_input is None else kv_input
    q = x @ p["wq"]
    k = kv_src @ p["wk"]
    v = kv_src @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, kv_src.shape[1], cfg.n_kv_heads, hd)
    v = v.reshape(B, kv_src.shape[1], cfg.n_kv_heads, hd)
    return q, k, v


# score tensors larger than this (elements) switch to the chunked
# online-softmax formulation — the flash recurrence expressed at HLO level so
# the SPMD partitioner can still shard it (the Pallas kernel is the
# single-chip fast path; this is the distributed-memory-safety path).
_CHUNKED_THRESHOLD = 1 << 22          # 4M score elements per (b, h)
_KV_CHUNK = 1024


def _masked_scores(qg, k, q_positions, k_lo, causal, window, kv_valid_len):
    """(B,Sq,Hkv,g,D)x(B,bk,Hkv,D) -> masked f32 scores (B,h,g,Sq,bk)."""
    D = qg.shape[-1]
    bk = k.shape[1]
    Sq = qg.shape[1]
    scores = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32
    ) / (D ** 0.5)
    kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, Sq, bk), 4)
    qpos = q_positions[:, None, None, :, None]
    mask = jnp.ones((1, 1, 1, Sq, bk), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window > 0:
        mask = mask & (kpos > qpos - window)
    if kv_valid_len is not None:
        mask = mask & (kpos < kv_valid_len[:, None, None, None, None])
    return jnp.where(mask, scores, -1e30)


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *,
         causal: bool, window: int, q_positions: jax.Array,
         kv_valid_len: Optional[jax.Array] = None) -> jax.Array:
    """Masked attention (XLA-partitionable).

    q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D); q_positions: (B, Sq) absolute
    positions of the queries in KV coordinates; kv_valid_len: (B,) or None.
    Large score tensors use the chunked online-softmax path.
    """
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, g, D)
    # NOTE (perf log iter 10, REFUTED): pinning the KV-head axis to "model"
    # here removes the score-einsum all-reduces seen in HLO, but costs +28%
    # memory-term in resharding transposes against the SP residual layout —
    # net regression, reverted.  See EXPERIMENTS.md §Perf.

    if Sq * Skv > _CHUNKED_THRESHOLD and Skv % _KV_CHUNK == 0 and Sq > 1:
        return _sdpa_chunked(qg, k, v, causal=causal, window=window,
                             q_positions=q_positions, kv_valid_len=kv_valid_len
                             ).reshape(B, Sq, Hq * D)

    scores = _masked_scores(qg, k, q_positions, 0, causal, window, kv_valid_len)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, Hq * D)


def _sdpa_chunked(qg, k, v, *, causal, window, q_positions, kv_valid_len):
    """Flash recurrence over KV chunks via lax.scan (O(Sq·chunk) memory).

    The chunk body is rematerialized on backward (checkpoint) so train-time
    peak memory holds one chunk's scores, not the full (Sq, Skv) product.
    """
    B, Sq, Hkv, g, D = qg.shape
    Skv = k.shape[1]
    n_chunks = Skv // _KV_CHUNK
    kc = k.reshape(B, n_chunks, _KV_CHUNK, Hkv, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, _KV_CHUNK, Hkv, D).transpose(1, 0, 2, 3, 4)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        ci, k_i, v_i = xs
        k_lo = ci * _KV_CHUNK
        s = _masked_scores(qg, k_i, q_positions, k_lo, causal, window,
                           kv_valid_len)                       # (B,h,g,Sq,bk)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_i.dtype), v_i)
        acc = acc * alpha[..., None].astype(acc.dtype) + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, g, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, g, Sq, D), v.dtype)
    idx = jnp.arange(n_chunks, dtype=jnp.int32)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0),
                                  (idx, kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
    return out.transpose(0, 3, 1, 2, 4)  # (B,Sq,Hkv,g,D)


def attention(p: Params, x: jax.Array, cfg: ModelConfig, *,
              kind: str, positions: jax.Array,
              cache: Optional[Tuple[jax.Array, jax.Array]] = None,
              cache_pos: Optional[jax.Array] = None,
              kv_input: Optional[jax.Array] = None,
              causal: bool = True):
    """One attention mixer.  kind: 'attn' (full) or 'swa' (window).

    Train/prefill: cache is None -> self-attention over x.
    Decode: cache=(k_cache, v_cache) with layout (B, S_cache, Hkv, D);
    ``cache_pos`` is the (traced) write position; for 'swa' the cache is a
    ring buffer of size window and writes wrap.  Returns (out, new_cache).
    """
    window = cfg.window if kind == "swa" else 0
    q, k, v = _proj_qkv(p, x, cfg, kv_input)
    if kv_input is None:  # rope only for self-attention
        q = rope(q, positions, cfg.rope_theta)
        if cache is None:
            k = rope(k, positions, cfg.rope_theta)
    new_cache = None

    if cache is not None:
        kc, vc = cache
        S_cache = kc.shape[1]
        if window > 0 and S_cache == window:
            # ring buffer: absolute position -> slot = pos % window
            slot = cache_pos % window
            k = rope(k, positions, cfg.rope_theta)
            kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
            # positions of ring slots: slot i holds the latest pos p with
            # p % window == i and p <= cache_pos
            idx = jnp.arange(window, dtype=jnp.int32)
            ring_pos = cache_pos - ((cache_pos - idx) % window)
            # ring_pos may exceed cache_pos only by construction error; mask
            # invalid (not yet written) slots via pos > cache_pos - window
            out = _ring_sdpa(q, kc, vc, ring_pos, cache_pos, window)
            new_cache = (kc, vc)
            out = out @ p["wo"]
            return out, new_cache
        # full cache: write at cache_pos, attend with causal mask
        k = rope(k, positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, cache_pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, cache_pos, 0, 0))
        out = sdpa(q, kc, vc, causal=causal, window=window,
                   q_positions=positions)
        new_cache = (kc, vc)
    else:
        out = sdpa(q, k, v, causal=causal, window=window, q_positions=positions)
    return out @ p["wo"], new_cache


def _ring_sdpa(q, kc, vc, ring_pos, cache_pos, window):
    """Attention over a ring-buffer KV: mask by true slot positions."""
    B, Sq, Hq, D = q.shape
    Hkv = kc.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, g, D)
    scores = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, kc, preferred_element_type=jnp.float32
    ) / (D ** 0.5)
    valid = (ring_pos <= cache_pos) & (ring_pos > cache_pos - window) & (ring_pos >= 0)
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(vc.dtype), vc)
    return out.reshape(B, Sq, Hq * D)


# ---------------------------------------------------------------------------
# FFN (GLU) and MoE
# ---------------------------------------------------------------------------
def ffn_params(key, d: int, f: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "wi": dense_init(k1, (d, 2 * f), dtype),   # fused gate||up
        "wo_f": dense_init(k2, (f, d), dtype),
    }


def ffn(p: Params, x: jax.Array) -> jax.Array:
    gu = x @ p["wi"]
    gate, up = jnp.split(gu, 2, axis=-1)
    return (jax.nn.silu(gate) * up) @ p["wo_f"]


def moe_params(key, cfg: ModelConfig, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    e = cfg.padded_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "we_i": dense_init(ks[1], (e, d, 2 * f), dtype),
        "we_o": dense_init(ks[2], (e, f, d), dtype),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        k1, k2 = jax.random.split(ks[3])
        p["shared_i"] = dense_init(k1, (d, 2 * fs), dtype)
        p["shared_o"] = dense_init(k2, (fs, d), dtype)
    return p


def _hierarchical_rank(onehot: jax.Array, flat_e: jax.Array,
                       block: int = 1024) -> jax.Array:
    """Exclusive per-expert rank of each row, two-level:

      1. block histograms -> exclusive cumsum over the (tiny) block axis;
      2. within-block exclusive prefix via a log-step Hillis-Steele scan
         (static shifts; linear work, VPU-friendly — the same scheme as the
         ``segment_scan`` Pallas kernel).
    """
    n, e = onehot.shape
    pad = (-n) % block
    oh = jnp.pad(onehot, ((0, pad), (0, 0)))
    nb = oh.shape[0] // block
    ohb = oh.reshape(nb, block, e)
    hist = ohb.sum(axis=1)                                   # (nb, E)
    block_off = jnp.cumsum(hist, axis=0) - hist              # (nb, E) exclusive

    intra = ohb
    d = 1
    while d < block:
        shifted = jnp.pad(intra, ((0, 0), (d, 0), (0, 0)))[:, :block, :]
        intra = intra + shifted
        d *= 2
    intra_excl = intra - ohb                                 # exclusive in-block

    excl = (block_off[:, None, :] + intra_excl).reshape(-1, e)[:n]
    return jnp.take_along_axis(
        excl, flat_e[:, None].astype(jnp.int32), axis=1)[:, 0]


def moe_ffn(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Top-k MoE with capacity dispatch.

    Two paths:
      * ambient mesh with a "model" axis -> explicit-EP ``shard_map`` path
        (``_moe_ffn_ep``): activations are DP-sharded/TP-replicated, so each
        expert shard *selects* its tokens locally (dispatch is collective-
        free) and the combine is ONE psum over "model" — the all-reduce
        Megatron TP needs after an FFN anyway.  This replaced a scatter-into-
        sharded-buffer formulation the SPMD partitioner turned into full
        dispatch-buffer all-reduces (~45 GiB/layer measured).
      * no mesh (unit tests, single chip) -> dense-buffer path below.
    """
    if hints.axis("model"):
        return _moe_ffn_ep(p, x, cfg)
    return _moe_ffn_dense(p, x, cfg)


def _moe_ffn_ep(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    import numpy as _np
    from jax.sharding import PartitionSpec as P

    mesh = jax.sharding.get_abstract_mesh()
    dp = hints.dp_axes()
    B, S, d = x.shape
    tp = mesh.shape["model"]
    e_pad, e_real, k = cfg.padded_experts, cfg.n_experts, cfg.top_k
    E_loc = e_pad // tp
    dp_size = int(_np.prod([mesh.shape[a] for a in dp])) if dp else 1
    if B % dp_size != 0:
        dp = None
        dp_size = 1
    # per-group capacity (GShard group = one data shard's tokens)
    T_loc = B * S // dp_size
    C_loc = int(cfg.capacity_factor * k * T_loc / e_real) + 1

    has_shared = "shared_i" in p
    # sequence-parallel I/O: residuals arrive seq-sharded over "model"
    # (Megatron-SP); gather once on entry, reduce-scatter on exit
    sp = S % tp == 0 and S > 1

    def body(xb, router, we_i, we_o, *shared):
        if sp:
            xb = jax.lax.all_gather(xb, "model", axis=1, tiled=True)
        Tl = xb.shape[0] * xb.shape[1]
        xt = xb.reshape(Tl, d)
        logits = xt.astype(jnp.float32) @ router
        if e_pad != e_real:
            logits = jnp.where(jnp.arange(e_pad)[None, :] >= e_real, -1e30, logits)
        gates, experts = jax.lax.top_k(logits, k)
        gates = jax.nn.softmax(gates, axis=-1).astype(xb.dtype)

        flat_e = experts.reshape(-1)
        onehot = (flat_e[:, None] == jnp.arange(e_pad, dtype=flat_e.dtype)[None, :]
                  ).astype(jnp.int32)
        rank = _hierarchical_rank(onehot, flat_e)
        keep = rank < C_loc

        e_lo = jax.lax.axis_index("model").astype(jnp.int32) * E_loc
        local = (flat_e >= e_lo) & (flat_e < e_lo + E_loc) & keep
        slot = jnp.where(local, (flat_e - e_lo) * C_loc + rank, E_loc * C_loc)
        token_idx = jnp.repeat(jnp.arange(Tl, dtype=jnp.int32), k)
        # slot-indexed dispatch: invert (choice -> slot) into (slot -> token)
        # so no (T·k, d) intermediate is ever materialized — buffers stay
        # (E_loc·C_loc, d)
        oob = E_loc * C_loc
        src = jnp.full((oob + 1,), Tl, jnp.int32).at[slot].set(
            token_idx, mode="drop")[:oob]
        w_slot = jnp.zeros((oob + 1,), xb.dtype).at[slot].set(
            gates.reshape(-1), mode="drop")[:oob]
        occupied = src < Tl
        buf = jnp.where(occupied[:, None],
                        xt[jnp.clip(src, 0, Tl - 1)], 0).reshape(E_loc, C_loc, d)

        gu = jnp.einsum("ecd,edf->ecf", buf, we_i)
        gate, up = jnp.split(gu, 2, axis=-1)
        h = jax.nn.silu(gate) * up
        out_e = jnp.einsum("ecf,efd->ecd", h, we_o).reshape(oob, d)

        yt = jnp.zeros((Tl, d), xb.dtype).at[jnp.where(occupied, src, Tl)].add(
            out_e * w_slot[:, None], mode="drop")

        if shared:  # TP-sharded shared experts ride the same reduction
            si, so = shared
            sgu = xt @ si
            sg, su = jnp.split(sgu, 2, axis=-1)
            yt = yt + (jax.nn.silu(sg) * su) @ so
        yb = yt.reshape(xb.shape)
        if sp:  # reduce-scatter back to the SP residual layout
            return jax.lax.psum_scatter(yb, "model", scatter_dimension=1,
                                        tiled=True)
        return jax.lax.psum(yb, "model")

    seq_spec = "model" if sp else None
    in_specs = [P(dp, seq_spec, None), P(None, None),
                P("model", None, None), P("model", None, None)]
    args = [x, p["router"], p["we_i"], p["we_o"]]
    if has_shared:
        in_specs += [P(None, "model"), P("model", None)]
        args += [p["shared_i"], p["shared_o"]]
    fn = jax.shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                       out_specs=P(dp, seq_spec, None), check_vma=False)
    return fn(*args)


def _moe_ffn_dense(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Dense-buffer fallback (no mesh): same math, global capacity."""
    B, S, d = x.shape
    T = B * S
    e_pad, e_real, k = cfg.padded_experts, cfg.n_experts, cfg.top_k
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"])
    if e_pad != e_real:
        pad_mask = jnp.arange(e_pad) >= e_real
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    gates, experts = jax.lax.top_k(logits, k)              # (T, k)
    gates = jax.nn.softmax(gates, axis=-1).astype(x.dtype)

    C = int(cfg.capacity_factor * k * T / e_real) + 1
    # rank of each (token, choice) within its expert via one-hot exclusive
    # cumsum (the hash_partition kernel's formulation).  NOT a global argsort:
    # rank order within an expert is irrelevant, and a sharded global sort
    # costs O(T·k) all-to-all rounds in SPMD (measured: ~45 GiB/layer of sort
    # collectives on the 16×16 mesh).  The cumsum is a hierarchical two-level
    # count (block-local one-hot sums + tiny cross-block cumsum) so it lowers
    # to linear-work reductions, not XLA's O(n·window) reduce-window cumsum.
    flat_e = experts.reshape(-1)                            # (T*k,)
    onehot = (flat_e[:, None] == jnp.arange(e_pad, dtype=flat_e.dtype)[None, :]
              ).astype(jnp.int32)                           # (T*k, E)
    rank = _hierarchical_rank(onehot, flat_e)

    keep = rank < C
    slot = jnp.where(keep, flat_e * C + rank, e_pad * C)    # OOB drops
    # dispatch: (E*C, d) buffer — EP-sharded on the expert axis; the scatter
    # from DP-sharded tokens is the real MoE all-to-all
    token_idx = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    buf = jnp.zeros((e_pad * C, d), x.dtype).at[slot].set(xt[token_idx], mode="drop")
    buf = hints.constrain(buf.reshape(e_pad, C, d), "model", None, None)

    gu = jnp.einsum("ecd,edf->ecf", buf, p["we_i"])          # (E, C, 2F)
    gate, up = jnp.split(gu, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    out_e = jnp.einsum("ecf,efd->ecd", h, p["we_o"])         # (E, C, d)
    out_e = hints.constrain(out_e, "model", None, None)

    # combine: weighted scatter back to (DP-sharded) tokens
    gathered = out_e.reshape(e_pad * C, d)[jnp.clip(slot, 0, e_pad * C - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = gates.reshape(-1)[:, None]
    yt = jnp.zeros((T, d), x.dtype).at[token_idx].add(gathered * w)
    yt = hints.constrain(yt, hints.dp_axes(), None)

    if "shared_i" in p:
        gu = xt @ p["shared_i"]
        gate, up = jnp.split(gu, 2, axis=-1)
        yt = yt + (jax.nn.silu(gate) * up) @ p["shared_o"]
    return yt.reshape(B, S, d)


# ---------------------------------------------------------------------------
# aux-loss (load balance) for MoE training
# ---------------------------------------------------------------------------
def moe_load_balance_loss(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    if cfg.padded_experts != cfg.n_experts:
        logits = jnp.where(jnp.arange(cfg.padded_experts) >= cfg.n_experts, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    _, top = jax.lax.top_k(logits, cfg.top_k)
    onehot = jax.nn.one_hot(top, cfg.padded_experts, dtype=jnp.float32).sum(1)
    frac_tokens = onehot.mean(0)
    frac_probs = probs.mean(0)
    return cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
