from repro.models.registry import ModelBundle, get_bundle, all_archs
