"""Encoder-decoder backbone (seamless-m4t-medium).

Encoder: bidirectional attention over precomputed audio-frame embeddings (the
modality frontend is a stub per the assignment — ``input_specs`` supplies
(B, S_src, frontend_dim) frames).  Decoder: causal self-attention +
cross-attention to encoder memory + FFN.  Decode caches both the growing
self-attention KV and the fixed cross-attention KV (projected once).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = _dtype(cfg)
    ks = jax.random.split(key, 6)
    d = cfg.d_model

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": jnp.zeros((d,), dtype),
            "attn": L.attn_params(k1, cfg, dtype),
            "norm2": jnp.zeros((d,), dtype),
            "ffn": L.ffn_params(k2, d, cfg.d_ff, dtype),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "norm1": jnp.zeros((d,), dtype),
            "self_attn": L.attn_params(k1, cfg, dtype),
            "norm_x": jnp.zeros((d,), dtype),
            "cross_attn": L.attn_params(k2, cfg, dtype, cross=True),
            "norm2": jnp.zeros((d,), dtype),
            "ffn": L.ffn_params(k3, d, cfg.d_ff, dtype),
        }

    ekeys = jax.random.split(ks[0], cfg.n_encoder_layers)
    dkeys = jax.random.split(ks[1], cfg.n_layers)
    enc = [enc_layer(k) for k in ekeys]
    dec = [dec_layer(k) for k in dkeys]
    return {
        "frontend_proj": L.dense_init(ks[2], (cfg.frontend_dim, d), dtype),
        "embed": L.dense_init(ks[3], (cfg.padded_vocab, d), dtype, scale=0.02),
        "enc_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "enc_norm": jnp.zeros((d,), dtype),
        "final_norm": jnp.zeros((d,), dtype),
        "lm_head": L.dense_init(ks[4], (d, cfg.padded_vocab), dtype),
    }


def abstract_params(cfg: ModelConfig) -> Params:
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


def encode(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, S_src, frontend_dim) -> memory (B, S_src, d)."""
    x = frames.astype(_dtype(cfg)) @ params["frontend_proj"]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(xc, lp):
        h = L.rmsnorm(lp["norm1"], xc, cfg.norm_eps)
        out, _ = L.attention(lp["attn"], h, cfg, kind="attn",
                             positions=positions, causal=False)
        xc = xc + out
        h = L.rmsnorm(lp["norm2"], xc, cfg.norm_eps)
        return xc + L.ffn(lp["ffn"], h), 0.0

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def init_cache(cfg: ModelConfig, batch: int, kv_len: int, src_len: int) -> Params:
    dtype = _dtype(cfg)
    hd = cfg.head_dim_
    n = cfg.n_layers
    kv = lambda s: jnp.zeros((n, batch, s, cfg.n_kv_heads, hd), dtype)
    return {"self_k": kv(kv_len), "self_v": kv(kv_len),
            "cross_k": kv(src_len), "cross_v": kv(src_len)}


def abstract_cache(cfg: ModelConfig, batch: int, kv_len: int, src_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, kv_len, src_len))


def prefill_cross(params: Params, cfg: ModelConfig, memory: jax.Array) -> Tuple:
    """Project encoder memory into per-layer cross K/V (done once)."""
    hd = cfg.head_dim_
    B, S, _ = memory.shape

    def body(_, lp):
        k = (memory @ lp["cross_attn"]["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
        v = (memory @ lp["cross_attn"]["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
        return None, (k, v)

    _, (ck, cv) = jax.lax.scan(body, None, params["dec_layers"])
    return ck, cv


def decode_forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,                 # (B, S_dec)
    memory: Optional[jax.Array] = None,   # (B, S_src, d) for train/prefill
    cache: Optional[Params] = None,
    cache_pos: Optional[jax.Array] = None,
    logits_slice: Optional[int] = None,
):
    """Decoder pass; train/prefill (cache=None, memory given) or decode step
    (cache given, cross K/V already in cache)."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    if cache_pos is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    else:
        positions = cache_pos + jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    hd = cfg.head_dim_

    def body(carry, scanned):
        xc = carry
        if cache is None:
            lp = scanned
            h = L.rmsnorm(lp["norm1"], xc, cfg.norm_eps)
            out, _ = L.attention(lp["self_attn"], h, cfg, kind="attn",
                                 positions=positions)
            xc = xc + out
            h = L.rmsnorm(lp["norm_x"], xc, cfg.norm_eps)
            out, _ = L.attention(lp["cross_attn"], h, cfg, kind="attn",
                                 positions=positions, kv_input=memory,
                                 causal=False)
            xc = xc + out
            h = L.rmsnorm(lp["norm2"], xc, cfg.norm_eps)
            xc = xc + L.ffn(lp["ffn"], h)
            return xc, 0.0
        lp, sk, sv, ck, cv = scanned
        h = L.rmsnorm(lp["norm1"], xc, cfg.norm_eps)
        out, (nsk, nsv) = L.attention(
            lp["self_attn"], h, cfg, kind="attn", positions=positions,
            cache=(sk, sv), cache_pos=cache_pos)
        xc = xc + out
        h = L.rmsnorm(lp["norm_x"], xc, cfg.norm_eps)
        q = (h @ lp["cross_attn"]["wq"]).reshape(B, S, cfg.n_heads, hd)
        out = L.sdpa(q, ck, cv, causal=False, window=0, q_positions=positions)
        xc = xc + out @ lp["cross_attn"]["wo"]
        h = L.rmsnorm(lp["norm2"], xc, cfg.norm_eps)
        xc = xc + L.ffn(lp["ffn"], h)
        return xc, (nsk, nsv)

    if cfg.remat:
        body = jax.checkpoint(body)
    if cache is None:
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
        new_cache = None
    else:
        x, (nsk, nsv) = jax.lax.scan(
            body, x,
            (params["dec_layers"], cache["self_k"], cache["self_v"],
             cache["cross_k"], cache["cross_v"]))
        new_cache = {"self_k": nsk, "self_v": nsv,
                     "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if logits_slice is not None:
        x = x[:, -logits_slice:, :]
    logits = x @ params["lm_head"]
    return logits, new_cache


def train_loss(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    from repro.models.lm import cross_entropy

    memory = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    logits, _ = decode_forward(params, cfg, tokens, memory=memory)
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((tokens.shape[0], 1), tokens.dtype)], axis=1)
    mask = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
    return cross_entropy(logits, labels, mask, cfg.vocab_size)
