"""Decoder-only LM covering the dense / MoE / hybrid / ssm / vlm families.

Layers are grouped into the config's repeating *pattern period* and scanned
with ``lax.scan`` over stacked period parameters — compile time at 48 layers ×
512 devices stays bounded by one period's HLO, and remat is applied per
period.  Non-uniform prefixes (deepseek's dense first layer) and pattern
tails (recurrentgemma's 26 = 8×3 + 2) are unscanned explicit layers.

Serving: ``init_cache`` builds the per-kind cache pytree (full KV, ring-buffer
KV for sliding-window layers, recurrent states for RG-LRU/xLSTM);
``forward(..., cache=..., cache_pos=...)`` is the decode step.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import hints
from repro.models import layers as L
from repro.models import recurrent as R

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_layer(key, kind: str, cfg: ModelConfig, dtype, ffn_type: str) -> Params:
    ks = jax.random.split(key, 3)
    p: Params = {"norm1": jnp.zeros((cfg.d_model,), dtype)}
    if kind in ("attn", "swa"):
        p["mixer"] = L.attn_params(ks[0], cfg, dtype)
    elif kind == "rglru":
        p["mixer"] = R.rglru_params(ks[0], cfg, dtype)
    elif kind == "mlstm":
        p["mixer"] = R.mlstm_params(ks[0], cfg, dtype)
    elif kind == "slstm":
        p["mixer"] = R.slstm_params(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if ffn_type == "dense":
        p["norm2"] = jnp.zeros((cfg.d_model,), dtype)
        p["ffn"] = L.ffn_params(ks[1], cfg.d_model, cfg.d_ff, dtype)
    elif ffn_type == "dense_first":
        p["norm2"] = jnp.zeros((cfg.d_model,), dtype)
        p["ffn"] = L.ffn_params(ks[1], cfg.d_model, cfg.dense_d_ff or cfg.d_ff, dtype)
    elif ffn_type == "moe":
        p["norm2"] = jnp.zeros((cfg.d_model,), dtype)
        p["ffn"] = L.moe_params(ks[1], cfg, dtype)
    elif ffn_type == "none":
        pass
    return p


def _layer_plan(cfg: ModelConfig):
    """(head_kinds, pattern, n_periods, tail_kinds) with ffn types."""
    def ffn_type(layer_idx: int) -> str:
        if cfg.d_ff == 0:
            return "none"
        if cfg.n_experts:
            return "dense_first" if layer_idx < cfg.first_dense_layers else "moe"
        return "dense"

    head = [(cfg.pattern[i % len(cfg.pattern)], ffn_type(i))
            for i in range(cfg.first_dense_layers)]
    eff = cfg.n_layers - cfg.first_dense_layers
    npd = eff // len(cfg.pattern)
    tail_n = eff % len(cfg.pattern)
    pattern = [(k, ffn_type(cfg.first_dense_layers)) for k in cfg.pattern]
    tail = [(cfg.pattern[i], ffn_type(cfg.n_layers - tail_n + i))
            for i in range(tail_n)]
    return head, pattern, npd, tail


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = _dtype(cfg)
    head, pattern, npd, tail = _layer_plan(cfg)
    keys = jax.random.split(key, 8)
    p: Params = {
        "embed": L.dense_init(keys[0], (cfg.padded_vocab, cfg.d_model), dtype, scale=0.02),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(keys[1], (cfg.d_model, cfg.padded_vocab), dtype)
    if cfg.frontend == "vision_patches":
        p["img_proj"] = L.dense_init(keys[2], (cfg.frontend_dim, cfg.d_model), dtype)

    hkeys = jax.random.split(keys[3], max(len(head), 1))
    p["head_layers"] = tuple(
        _init_layer(hkeys[i], k, cfg, dtype, ft) for i, (k, ft) in enumerate(head)
    )

    if npd:
        pkeys = jax.random.split(keys[4], npd)

        def one_period(k):
            sk = jax.random.split(k, len(pattern))
            return {
                f"slot{i}": _init_layer(sk[i], kind, cfg, dtype, ft)
                for i, (kind, ft) in enumerate(pattern)
            }

        periods = [one_period(k) for k in pkeys]
        p["periods"] = jax.tree.map(lambda *xs: jnp.stack(xs), *periods)
    else:
        p["periods"] = {}

    tkeys = jax.random.split(keys[5], max(len(tail), 1))
    p["tail_layers"] = tuple(
        _init_layer(tkeys[i], k, cfg, dtype, ft) for i, (k, ft) in enumerate(tail)
    )
    return p


def abstract_params(cfg: ModelConfig) -> Params:
    """Shape/dtype-only params (dry-run: no allocation)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------
def _init_layer_cache(kind: str, cfg: ModelConfig, batch: int, kv_len: int, dtype):
    hd = cfg.head_dim_
    if kind == "attn":
        shape = (batch, kv_len, cfg.n_kv_heads, hd)
        return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
    if kind == "swa":
        w = min(cfg.window, kv_len) if cfg.window else kv_len
        shape = (batch, w, cfg.n_kv_heads, hd)
        return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
    if kind == "rglru":
        return R.rglru_init_state(cfg, batch, dtype)
    if kind == "mlstm":
        return R.mlstm_init_state(cfg, batch)
    if kind == "slstm":
        return R.slstm_init_state(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, kv_len: int) -> Params:
    dtype = _dtype(cfg)
    head, pattern, npd, tail = _layer_plan(cfg)

    def layer_cache(kind):
        return _init_layer_cache(kind, cfg, batch, kv_len, dtype)

    cache: Params = {
        "head_layers": tuple(layer_cache(k) for k, _ in head),
        "tail_layers": tuple(layer_cache(k) for k, _ in tail),
    }
    if npd:
        one = {f"slot{i}": layer_cache(kind) for i, (kind, _) in enumerate(pattern)}
        cache["periods"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (npd,) + x.shape), one
        )
    else:
        cache["periods"] = {}
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, kv_len: int) -> Params:
    return jax.eval_shape(lambda: init_cache(cfg, batch, kv_len))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _layer_apply(lp: Params, x, kind: str, ffn_type: str, cfg: ModelConfig,
                 positions, cache=None, cache_pos=None):
    # Megatron-SP layout hint: the residual stream between blocks is sequence-
    # sharded over the model axis (the partitioner then materializes
    # all-gather/reduce-scatter pairs around the TP matmuls instead of full
    # f32 activation all-reduces, and norm/residual work shards 16-way).
    # Applied only when S divides the axis (train/prefill, not decode).
    if cache is None:
        x = hints.constrain(x, hints.dp_axes(), "model", None)
    mixer_in = L.rmsnorm(lp["norm1"], x, cfg.norm_eps)
    if kind in ("attn", "swa"):
        out, new_cache = L.attention(
            lp["mixer"], mixer_in, cfg, kind=kind, positions=positions,
            cache=cache, cache_pos=cache_pos,
        )
    elif kind == "rglru":
        out, new_cache = R.rglru(lp["mixer"], mixer_in, cfg, state=cache)
    elif kind == "mlstm":
        out, new_cache = R.mlstm(lp["mixer"], mixer_in, cfg, state=cache)
    elif kind == "slstm":
        out, new_cache = R.slstm(lp["mixer"], mixer_in, cfg, state=cache)
    else:
        raise ValueError(kind)
    x = x + out
    if ffn_type != "none":
        h = L.rmsnorm(lp["norm2"], x, cfg.norm_eps)
        if ffn_type == "moe":
            x = x + L.moe_ffn(lp["ffn"], h, cfg)
        else:
            x = x + L.ffn(lp["ffn"], h)
    return x, new_cache


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,                    # (B, S) int32
    *,
    image_embeds: Optional[jax.Array] = None,
    cache: Optional[Params] = None,
    cache_pos: Optional[jax.Array] = None,  # scalar int32 (decode)
    return_cache: bool = False,
    logits_slice: Optional[int] = None,   # only last N positions' logits
):
    """Returns (logits, new_cache_or_None).

    Train/prefill: cache=None; positions are [0, S).
    Decode: cache + cache_pos; positions are cache_pos + [0, S).
    """
    head, pattern, npd, tail = _layer_plan(cfg)
    B, S = tokens.shape
    x = params["embed"][tokens]
    if cfg.frontend == "vision_patches" and image_embeds is not None:
        img = image_embeds.astype(x.dtype) @ params["img_proj"]
        n_img = img.shape[1]
        img_pad = jnp.zeros((B, S - n_img, x.shape[-1]), x.dtype)
        is_img = (jnp.arange(S) < n_img)[None, :, None]
        x = jnp.where(is_img, jnp.concatenate([img, img_pad], axis=1), x)

    if cache_pos is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    else:
        positions = cache_pos + jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    new_cache: Params = {"head_layers": [], "tail_layers": [], "periods": {}}

    def run_explicit(x, layer_list, kinds, caches):
        new = []
        for i, (kind, ft) in enumerate(kinds):
            c = caches[i] if caches is not None else None
            x, nc = _layer_apply(layer_list[i], x, kind, ft, cfg, positions,
                                 cache=c, cache_pos=cache_pos)
            new.append(nc)
        return x, tuple(new)

    x, nh = run_explicit(x, params["head_layers"], head,
                         cache["head_layers"] if cache else None)
    new_cache["head_layers"] = nh

    if npd:
        def period_body(xc, per):
            per_params, per_cache = per
            ncs = {}
            xx = xc
            for i, (kind, ft) in enumerate(pattern):
                c = per_cache[f"slot{i}"] if per_cache is not None else None

                def one_layer(lp_, xx_, c_, *, _kind=kind, _ft=ft):
                    return _layer_apply(lp_, xx_, _kind, _ft, cfg, positions,
                                        cache=c_, cache_pos=cache_pos)

                # remat per LAYER, not per period: peak activation memory is
                # one layer's intermediates even when the pattern period is
                # long (gemma3: 6 layers/period -> ~6x less live remat state)
                if cfg.remat:
                    one_layer = jax.checkpoint(one_layer)
                xx, nc = one_layer(per_params[f"slot{i}"], xx, c)
                ncs[f"slot{i}"] = nc
            return xx, ncs

        body = period_body
        per_cache = cache["periods"] if cache else None
        if per_cache is None:
            # scan without cache: xs = stacked params only
            x, _ = jax.lax.scan(
                lambda xc, pp: (body(xc, (pp, None))[0], 0.0),
                x, params["periods"])
            new_cache["periods"] = {}
        else:
            # KV caches ride the scan CARRY with in-place dynamic updates —
            # the xs->ys formulation double-buffers the whole cache (measured
            # +cache-size temp on 32k decode); carry updates alias in place.
            def cache_body(carry, xs):
                xx, cache_all = carry
                pp, i = xs
                pc = jax.tree.map(
                    lambda c: jax.lax.dynamic_index_in_dim(c, i, 0,
                                                           keepdims=False),
                    cache_all)
                xx, ncs = body(xx, (pp, pc))
                cache_all = jax.tree.map(
                    lambda c, n: jax.lax.dynamic_update_index_in_dim(
                        c, n.astype(c.dtype), i, 0),
                    cache_all, ncs)
                return (xx, cache_all), None

            (x, ncs), _ = jax.lax.scan(
                cache_body, (x, per_cache),
                (params["periods"], jnp.arange(npd, dtype=jnp.int32)))
            new_cache["periods"] = ncs

    x, nt = run_explicit(x, params["tail_layers"], tail,
                         cache["tail_layers"] if cache else None)
    new_cache["tail_layers"] = nt

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if logits_slice is not None:
        x = x[:, -logits_slice:, :]
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = x @ params["lm_head"]
    # logits MUST stay vocab-sharded: under SP the partitioner otherwise picks
    # a seq-sharded full-vocab layout (measured 4 GiB/device f32 logits on
    # gemma3's 262k vocab); CE reduces over the sharded vocab axis instead.
    logits = hints.constrain(logits, hints.dp_axes(), None, "model")
    return logits, (new_cache if (return_cache or cache is not None) else None)


# ---------------------------------------------------------------------------
# losses / steps
# ---------------------------------------------------------------------------
def cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array,
                  vocab_size: int) -> jax.Array:
    """Masked CE over (possibly padded, vocab-sharded) logits.

    Sharding-friendly formulation: the gold logit is an iota-compare masked
    reduction (elementwise over the sharded vocab axis + all-reduce), NOT a
    take_along_axis — a gather over a sharded axis makes the partitioner
    all-gather the whole logits tensor.  The f32 upcast + pad masking fuse
    into both reductions (no materialized f32 copy).
    """
    V = logits.shape[-1]
    vidx = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    x = logits.astype(jnp.float32)
    if V != vocab_size:
        x = jnp.where(vidx < vocab_size, x, -1e30)
    lse = jax.nn.logsumexp(x, axis=-1)
    gold = jnp.sum(jnp.where(vidx == labels[..., None], x, 0.0), axis=-1)
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def train_loss(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    tokens = batch["tokens"]
    image_embeds = batch.get("image_embeds")
    logits, _ = forward(params, cfg, tokens, image_embeds=image_embeds)
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((tokens.shape[0], 1), tokens.dtype)], axis=1)
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(tokens, jnp.float32)
    mask = mask.astype(jnp.float32).at[:, -1].set(0.0)
    if cfg.frontend == "vision_patches":
        is_img = jnp.arange(tokens.shape[1]) < cfg.n_frontend_tokens
        mask = mask * (~is_img)[None, :].astype(jnp.float32)
    loss = cross_entropy(logits, labels, mask, cfg.vocab_size)
    if cfg.n_experts:
        # load-balance aux loss on the first MoE layer's router (cheap proxy;
        # per-layer routers inside the scan would need a scan-carried sum)
        lp = (params["periods"] or {})
        if lp:
            first = jax.tree.map(lambda v: v[0], lp["slot0"])
            if "router" in first.get("ffn", {}):
                h = params["embed"][tokens]
                loss = loss + 0.01 * L.moe_load_balance_loss(first["ffn"], h, cfg)
    return loss
