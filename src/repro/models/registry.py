"""Architecture registry: ``--arch <id>`` -> model functions + input specs.

Exposes a uniform protocol consumed by the launcher, dry-run, tests and
benchmarks:

  bundle = get_bundle("gemma3-12b")
  bundle.init(key)                    -> params (real arrays)
  bundle.abstract_params()            -> ShapeDtypeStruct pytree
  bundle.train_loss(params, batch)    -> scalar
  bundle.prefill(params, batch)       -> last-token logits
  bundle.decode(params, cache, batch) -> (logits, new_cache)
  bundle.input_specs(shape_cell)      -> {name: ShapeDtypeStruct}  (+ cache)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.archs import ARCHS, LONG_CONTEXT_OK, get_config, reduced_config
from repro.configs.base import ModelConfig, ShapeCell, SHAPES
from repro.models import encdec as ED
from repro.models import lm as LM


def _src_len(seq_len: int) -> int:
    """Encoder frame count for enc-dec shapes (audio frames ~ seq/4)."""
    return max(64, seq_len // 4)


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig

    # -- params ----------------------------------------------------------------
    def init(self, key) -> Any:
        if self.cfg.is_encdec:
            return ED.init_params(self.cfg, key)
        return LM.init_params(self.cfg, key)

    def abstract_params(self) -> Any:
        if self.cfg.is_encdec:
            return ED.abstract_params(self.cfg)
        return LM.abstract_params(self.cfg)

    # -- steps -------------------------------------------------------------------
    def train_loss(self, params, batch) -> jax.Array:
        if self.cfg.is_encdec:
            return ED.train_loss(params, self.cfg, batch)
        return LM.train_loss(params, self.cfg, batch)

    def prefill(self, params, batch) -> jax.Array:
        """Full-sequence forward emitting the last position's logits."""
        if self.cfg.is_encdec:
            memory = ED.encode(params, self.cfg, batch["frames"])
            logits, _ = ED.decode_forward(params, self.cfg, batch["tokens"],
                                          memory=memory, logits_slice=1)
            return logits
        logits, _ = LM.forward(params, self.cfg, batch["tokens"],
                               image_embeds=batch.get("image_embeds"),
                               logits_slice=1)
        return logits

    def decode(self, params, cache, batch):
        """One-token decode step against a kv_len cache."""
        if self.cfg.is_encdec:
            return ED.decode_forward(params, self.cfg, batch["tokens"],
                                     cache=cache, cache_pos=batch["pos"])
        return LM.forward(params, self.cfg, batch["tokens"], cache=cache,
                          cache_pos=batch["pos"])

    # -- caches -------------------------------------------------------------------
    def init_cache(self, batch: int, kv_len: int):
        if self.cfg.is_encdec:
            return ED.init_cache(self.cfg, batch, kv_len, _src_len(kv_len))
        return LM.init_cache(self.cfg, batch, kv_len)

    def abstract_cache(self, batch: int, kv_len: int):
        if self.cfg.is_encdec:
            return ED.abstract_cache(self.cfg, batch, kv_len, _src_len(kv_len))
        return LM.abstract_cache(self.cfg, batch, kv_len)

    # -- input specs -------------------------------------------------------------
    def input_specs(self, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of the cell."""
        B, S = cell.global_batch, cell.seq_len
        i32 = jnp.int32
        if cell.kind == "train":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
            if self.cfg.is_encdec:
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, _src_len(S), self.cfg.frontend_dim), jnp.bfloat16)
            if self.cfg.frontend == "vision_patches":
                specs["image_embeds"] = jax.ShapeDtypeStruct(
                    (B, self.cfg.n_frontend_tokens, self.cfg.frontend_dim),
                    jnp.bfloat16)
            return specs
        if cell.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
            if self.cfg.is_encdec:
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, _src_len(S), self.cfg.frontend_dim), jnp.bfloat16)
            if self.cfg.frontend == "vision_patches":
                specs["image_embeds"] = jax.ShapeDtypeStruct(
                    (B, self.cfg.n_frontend_tokens, self.cfg.frontend_dim),
                    jnp.bfloat16)
            return specs
        # decode: one new token + write position
        return {
            "tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
        }

    def supports(self, cell: ShapeCell) -> bool:
        if cell.name == "long_500k":
            return self.cfg.name in LONG_CONTEXT_OK
        return True


@functools.lru_cache(maxsize=None)
def get_bundle(name: str, reduced: bool = False) -> ModelBundle:
    cfg = reduced_config(name) if reduced else get_config(name)
    return ModelBundle(cfg)


def all_archs():
    return sorted(ARCHS)
