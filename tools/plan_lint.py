#!/usr/bin/env python
"""plan-lint — CI gate running the static plan verifier over the goldens.

Three halves, all must pass:

1. **Golden plans are diagnostic-clean.**  The two example studies
   (quickstart, cohort_study — the same shapes ``tests/goldens`` pins) are
   optimized under both predicate engines and fed to ``analyze()``.  Any
   ``error`` or ``warn`` diagnostic fails the gate; ``info`` notes (SP009
   demotion, SP010 unaligned concat) are reported but allowed — they flag
   performance texture, not defects.

2. **Golden wire specs compile clean.**  Every ``tests/goldens/*_spec.json``
   artifact must pass strict SPEC validation, compile onto a Study, and
   produce a diagnostic-clean optimized plan under both predicate engines —
   the public spec artifacts stay as trustworthy as the Python goldens.

3. **Seeded defects all fire.**  Every fixture in ``study/defects.py``
   (one per SPnnn code) must produce exactly its expected diagnostic —
   proving the analyzer still detects each defect class end to end.

Run:  PYTHONPATH=src python tools/plan_lint.py
Exit: 0 clean, 1 violations.
"""
from __future__ import annotations

import glob
import json
import os
import sys

from repro.study.analyze import DIAGNOSTIC_CODES, analyze, format_diagnostics
from repro.study.defects import all_defects, golden_studies
from repro.study.spec import compile_spec, validate_spec

GOLDEN_SPEC_GLOB = os.path.join(os.path.dirname(__file__), "..", "tests",
                                "goldens", "*_spec.json")


def lint_goldens() -> int:
    failures = 0
    for name, study in golden_studies().items():
        for engine in ("pallas", "jnp"):
            plan = study.optimized_plan(predicate_engine=engine)
            diags = analyze(plan, n_patients=study.n_patients)
            bad = [d for d in diags if d.severity in ("error", "warn")]
            info = [d for d in diags if d.severity == "info"]
            status = "FAIL" if bad else "ok"
            print(f"  {status:4s} {name:14s} engine={engine:6s} "
                  f"{len(plan.nodes):3d} nodes  "
                  f"{len(bad)} error/warn, {len(info)} info")
            if bad:
                print(format_diagnostics(bad))
                failures += 1
            for d in info:
                print(f"         note: {d.code} @ node {d.node}: {d.message}")
    return failures


def lint_golden_specs() -> int:
    paths = sorted(glob.glob(GOLDEN_SPEC_GLOB))
    if not paths:
        print("  FAIL no tests/goldens/*_spec.json artifacts found")
        return 1
    failures = 0
    for path in paths:
        name = os.path.basename(path)
        with open(path) as f:
            spec = json.load(f)
        issues = validate_spec(spec)
        if issues:
            print(f"  FAIL {name}: {len(issues)} validation issue(s)")
            for i in issues:
                print(f"       {i}")
            failures += 1
            continue
        study = compile_spec(spec)
        for engine in ("pallas", "jnp"):
            plan = study.optimized_plan(predicate_engine=engine)
            diags = analyze(plan, n_patients=study.n_patients)
            bad = [d for d in diags if d.severity in ("error", "warn")]
            status = "FAIL" if bad else "ok"
            print(f"  {status:4s} {name:24s} engine={engine:6s} "
                  f"{len(plan.nodes):3d} nodes  {len(bad)} error/warn")
            if bad:
                print(format_diagnostics(bad))
                failures += 1
    return failures


def lint_defects() -> int:
    failures = 0
    for code, plan, kwargs in all_defects():
        diags = analyze(plan, **kwargs)
        hit = [d for d in diags if d.code == code]
        sev, summary = DIAGNOSTIC_CODES[code]
        if hit:
            print(f"  ok   {code} ({sev:5s}) fires: {summary}")
        else:
            print(f"  FAIL {code} ({sev:5s}) did NOT fire: {summary}")
            print("       got: " + (format_diagnostics(diags) or "(clean)"))
            failures += 1
    return failures


def main() -> int:
    print("golden plans (must be free of error/warn diagnostics):")
    f1 = lint_goldens()
    print("golden wire specs (must validate, compile, and analyze clean):")
    f3 = lint_golden_specs()
    print(f"seeded defects (each of the {len(DIAGNOSTIC_CODES)} codes "
          f"must fire on its fixture):")
    f2 = lint_defects()
    if f1 or f2 or f3:
        print(f"\nplan-lint: FAILED ({f1} dirty golden plan(s), "
              f"{f3} dirty golden spec(s), {f2} silent defect(s))")
        return 1
    print("plan-lint: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
