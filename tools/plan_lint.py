#!/usr/bin/env python
"""plan-lint — CI gate running the static plan verifier over the goldens.

Two halves, both must pass:

1. **Golden plans are diagnostic-clean.**  The two example studies
   (quickstart, cohort_study — the same shapes ``tests/goldens`` pins) are
   optimized under both predicate engines and fed to ``analyze()``.  Any
   ``error`` or ``warn`` diagnostic fails the gate; ``info`` notes (SP009
   demotion, SP010 unaligned concat) are reported but allowed — they flag
   performance texture, not defects.

2. **Seeded defects all fire.**  Every fixture in ``study/defects.py``
   (one per SPnnn code) must produce exactly its expected diagnostic —
   proving the analyzer still detects each defect class end to end.

Run:  PYTHONPATH=src python tools/plan_lint.py
Exit: 0 clean, 1 violations.
"""
from __future__ import annotations

import sys

from repro.study.analyze import DIAGNOSTIC_CODES, analyze, format_diagnostics
from repro.study.defects import all_defects, golden_studies


def lint_goldens() -> int:
    failures = 0
    for name, study in golden_studies().items():
        for engine in ("pallas", "jnp"):
            plan = study.optimized_plan(predicate_engine=engine)
            diags = analyze(plan, n_patients=study.n_patients)
            bad = [d for d in diags if d.severity in ("error", "warn")]
            info = [d for d in diags if d.severity == "info"]
            status = "FAIL" if bad else "ok"
            print(f"  {status:4s} {name:14s} engine={engine:6s} "
                  f"{len(plan.nodes):3d} nodes  "
                  f"{len(bad)} error/warn, {len(info)} info")
            if bad:
                print(format_diagnostics(bad))
                failures += 1
            for d in info:
                print(f"         note: {d.code} @ node {d.node}: {d.message}")
    return failures


def lint_defects() -> int:
    failures = 0
    for code, plan, kwargs in all_defects():
        diags = analyze(plan, **kwargs)
        hit = [d for d in diags if d.code == code]
        sev, summary = DIAGNOSTIC_CODES[code]
        if hit:
            print(f"  ok   {code} ({sev:5s}) fires: {summary}")
        else:
            print(f"  FAIL {code} ({sev:5s}) did NOT fire: {summary}")
            print("       got: " + (format_diagnostics(diags) or "(clean)"))
            failures += 1
    return failures


def main() -> int:
    print("golden plans (must be free of error/warn diagnostics):")
    f1 = lint_goldens()
    print(f"seeded defects (each of the {len(DIAGNOSTIC_CODES)} codes "
          f"must fire on its fixture):")
    f2 = lint_defects()
    if f1 or f2:
        print(f"\nplan-lint: FAILED ({f1} dirty golden plan(s), "
              f"{f2} silent defect(s))")
        return 1
    print("plan-lint: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
