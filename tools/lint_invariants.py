#!/usr/bin/env python
"""Repo invariant linter — AST-level rules the test suite can't see.

Three rule families, each guarding an invariant earlier PRs established:

R1  bitset discipline — ``valid_bool()`` / ``valid_numpy()`` /
    ``bitset.unpack`` / ``unpack_np`` expand packed validity words to a bool
    (or numpy) row mask.  That expansion is the exact cost the bitset-native
    redesign removed from the hot path, so new call sites may appear only in
    the sanctioned modules below (sinks that genuinely need per-row masks:
    sorts/segment folds/host export) — anywhere else is a lint error.

R2  kernel determinism — ``src/repro/kernels`` must stay replayable: no
    wall-clock, RNG, or entropy calls inside kernel modules.  Differential
    tests (pallas vs jnp vs numpy reference) rely on bit-identical reruns.

R3  op-registry consistency — every plan op must be registered in
    ``plan.OP_KINDS`` with a kind signature, and the op sets must tile it
    exactly.  ``study/analyze.py`` kind-checks against OP_KINDS (SP012/13),
    so an op missing there silently escapes static analysis.

Run:  PYTHONPATH=src python tools/lint_invariants.py
Exit: 0 clean, 1 violations (printed one per line, file:line).
"""
from __future__ import annotations

import ast
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
SRC = REPO / "src" / "repro"

# R1: modules allowed to expand packed validity to a bool/numpy row mask.
UNPACK_ALLOWLIST = {
    "core/bitset.py",         # defines unpack/unpack_np
    "core/columnar.py",       # valid_bool()/valid_numpy() accessors + concat
    "core/cohort.py",         # subject-bitset -> membership mask export
    "core/stats.py",          # per-row masks for segment statistics
    "core/feature_driver.py", # host-side featurization export
    "core/transformers.py",   # host-side study transformers
    "core/flattening.py",     # hash_partition's per-row shard routing
    "study/executor.py",      # jnp fallback engine + host boundary
    "study/expr.py",          # jnp mask algebra (the value-generic engine)
    "study/optimizer.py",     # constant-fold over materialized host tables
    "data/chunkstore.py",     # partition-time row counts + key ranges (host)
}
UNPACK_NAMES = {"valid_bool", "valid_numpy", "unpack", "unpack_np"}

# R2: forbidden call prefixes inside src/repro/kernels (determinism).
NONDET_PATTERNS = [
    re.compile(p) for p in (
        r"^time\.", r"^datetime\.", r"^random\.", r"^np\.random\.",
        r"^numpy\.random\.", r"^os\.urandom$", r"^secrets\.",
    )
]


def _dotted(node: ast.AST) -> str:
    """Render a call target as a dotted path ('np.random.rand') or ''."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def lint_unpack_discipline() -> list:
    errs = []
    for path in sorted(SRC.rglob("*.py")):
        rel = path.relative_to(SRC).as_posix()
        if rel in UNPACK_ALLOWLIST:
            continue
        tree = ast.parse(path.read_text(), filename=str(path))
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            name = f.attr if isinstance(f, ast.Attribute) else (
                f.id if isinstance(f, ast.Name) else "")
            if name in UNPACK_NAMES:
                errs.append(
                    f"{path.relative_to(REPO)}:{node.lineno}: R1 "
                    f"{name}() expands packed validity outside the "
                    f"sanctioned modules (see tools/lint_invariants.py)")
    return errs


def lint_kernel_determinism() -> list:
    errs = []
    for path in sorted((SRC / "kernels").glob("*.py")):
        tree = ast.parse(path.read_text(), filename=str(path))
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func)
            if any(p.search(dotted) for p in NONDET_PATTERNS):
                errs.append(
                    f"{path.relative_to(REPO)}:{node.lineno}: R2 "
                    f"nondeterministic call {dotted}() in a kernel module "
                    f"(kernels must replay bit-identically)")
    return errs


def lint_op_registry() -> list:
    from repro.study import plan as P

    errs = []
    registered = set(P.OP_KINDS)
    declared = P.TABLE_OPS | P.COHORT_OPS | P.HOST_OPS
    for op in sorted(declared - registered):
        errs.append(f"src/repro/study/plan.py: R3 op {op!r} in an op set "
                    f"but missing from OP_KINDS")
    for op in sorted(registered - declared):
        errs.append(f"src/repro/study/plan.py: R3 op {op!r} in OP_KINDS but "
                    f"absent from TABLE_OPS|COHORT_OPS|HOST_OPS")
    if not P.PREDICATE_OPS <= P.TABLE_OPS:
        errs.append("src/repro/study/plan.py: R3 PREDICATE_OPS must be a "
                    "subset of TABLE_OPS")
    if not P.JOIN_OPS <= P.TABLE_OPS:
        errs.append("src/repro/study/plan.py: R3 JOIN_OPS must be a subset "
                    "of TABLE_OPS")
    # every op the PlanBuilder sugar emits must be registered
    plan_src = (SRC / "study" / "plan.py").read_text()
    tree = ast.parse(plan_src, filename="plan.py")
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add" and node.args
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)):
            op = node.args[0].value
            if op not in registered:
                errs.append(f"src/repro/study/plan.py:{node.lineno}: R3 "
                            f"builder emits op {op!r} not in OP_KINDS")
    return errs


def main() -> int:
    errs = (lint_unpack_discipline() + lint_kernel_determinism()
            + lint_op_registry())
    for e in errs:
        print(e)
    n_files = len(list(SRC.rglob("*.py")))
    if errs:
        print(f"\nlint_invariants: {len(errs)} violation(s) across "
              f"{n_files} source files")
        return 1
    print(f"lint_invariants: OK ({n_files} source files, 3 rule families)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
