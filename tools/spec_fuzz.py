#!/usr/bin/env python
"""spec-fuzz — CI gate driving the differential spec fuzzer.

Generates ``n`` wire specs from one seed: half valid by construction (each
compiled fresh three times and executed via ``predicate_engine="jnp"``,
``predicate_engine="pallas"`` and the chunked out-of-core path, results
asserted bit-identical, analyzer emptiness verdicts cross-checked against
executed counts), half corrupted one field at a time (each asserted to be
rejected with its exact ``SPEC-nnn`` catalog code, never a traceback).

Run:  PYTHONPATH=src python tools/spec_fuzz.py --n 200 --seed 0
      --no-execute restricts the valid half to validate+compile+plan
      (structural smoke); --out writes the machine-readable report.
Exit: 0 clean, 1 any differential/rejection/crash finding.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.study.fuzz import run_corpus


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=200,
                    help="corpus size (half valid, half mutated)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n-patients", type=int, default=200,
                    help="synthetic star size for the differential runs")
    ap.add_argument("--no-execute", action="store_true",
                    help="skip engine execution; validate+compile only")
    ap.add_argument("--out", default=None,
                    help="write the JSON report to this path")
    args = ap.parse_args(argv)

    t0 = time.time()
    report = run_corpus(n=args.n, seed=args.seed,
                        n_patients=args.n_patients,
                        execute=not args.no_execute)
    dt = time.time() - t0
    print(report.summary())
    print(f"  ({dt:.1f}s)")
    if args.out:
        payload = dict(report.to_json(), elapsed_s=round(dt, 2))
        with open(args.out, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"  report -> {args.out}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
